"""Property-based differential suite over generated designs.

For dozens of generator seeds x several depth configurations, the
discrete-event oracle, the trace-based worklist, and (where jax is
available) the fixpoint and pallas backends must agree on latency and
deadlock verdicts, and the functional outputs must match each design's
numpy reference.  Every assertion message carries the reproducing seed,
so a failure here is one ``python -m repro.launch.fuzz`` invocation away
from a minimal corpus entry.
"""

import glob
import importlib.util
import json
import os

import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.designs.generate import (DesignSpec, StageSpec, generate_design,
                                    shrink_spec, spec_from_seed)
from repro.launch.fuzz import depth_configs, differential_check, fuzz_one

HAS_JAX = importlib.util.find_spec("jax") is not None
CORPUS_DIR = os.path.join(os.path.dirname(__file__), "fuzz_corpus")


def _assert_clean(seed: int, quick: bool = True, backends=("worklist",),
                  n_random: int = 3):
    gen = generate_design(seed, quick=quick)
    mism, n_rows = differential_check(gen, backends=backends,
                                      n_random=n_random)
    assert not mism, (
        f"reproducing seed {seed}: {mism[0].kind} on {mism[0].backend} at "
        f"depths {mism[0].depths}: {mism[0].detail}\n"
        f"spec: {gen.spec.dumps()}")
    assert n_rows >= 3


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=5000))
def test_oracle_vs_worklist_differential(seed):
    """Oracle and worklist agree (latency + deadlock + functional) on
    arbitrary generated designs."""
    _assert_clean(seed, quick=True, backends=("worklist",))


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=500), st.booleans())
def test_full_size_designs_also_agree(seed, use_phase_bias):
    """Non-quick (full-size) designs agree too; the boolean just spreads
    the examples across two independent seed streams."""
    _assert_clean(seed + (7919 if use_phase_bias else 0), quick=False,
                  backends=("worklist",), n_random=2)


@pytest.mark.skipif(not HAS_JAX, reason="jax not installed")
@pytest.mark.parametrize("seed", [0, 3, 11, 17, 29, 41, 57, 93])
def test_oracle_vs_fixpoint_differential(seed):
    """The jit/vmap fixpoint backend matches the oracle on generated
    designs (dispatch escalation included)."""
    _assert_clean(seed, quick=True, backends=("fixpoint",))


@pytest.mark.skipif(not HAS_JAX, reason="jax not installed")
@pytest.mark.parametrize("seed", [5, 23])
def test_oracle_vs_pallas_differential(seed):
    """The pallas kernel (interpret mode on CPU) matches the oracle."""
    _assert_clean(seed, quick=True, backends=("pallas",))


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6))
def test_spec_roundtrip(seed):
    """spec -> JSON -> spec is the identity (corpus files depend on it)."""
    spec = spec_from_seed(seed, quick=bool(seed % 2))
    assert DesignSpec.loads(spec.dumps()) == spec


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2000),
       st.one_of(st.just(1), st.integers(min_value=2, max_value=6)))
def test_depth_configs_cover_corners(seed, n_random):
    """The differential depth matrix always contains the all-1 corner and
    the upper-bound vector, with every row in [1, upper]."""
    from repro.core.simgraph import build_simgraph
    gen = generate_design(seed, quick=True)
    g = build_simgraph(gen.design)
    m = depth_configs(g, np.random.default_rng(seed), n_random=n_random)
    u = np.maximum(g.upper_bounds, 1)
    assert (m >= 1).all() and (m <= u[None, :]).all()
    assert any((row == 1).all() for row in m)
    assert any((row == u).all() for row in m)


def test_shrink_finds_minimal_spec():
    """The shrinker reaches a local minimum: the failure predicate still
    holds, and no single structural reduction preserves it."""
    spec = spec_from_seed(1234, quick=False)
    spec.stages.append(StageSpec("router", {"ii": 2}))

    def still_fails(s: DesignSpec) -> bool:
        # synthetic "bug": any design with a router stage and n >= 4
        return s.n >= 4 and any(st_.kind == "router" for st_ in s.stages)

    small = shrink_spec(spec, still_fails)
    assert still_fails(small)
    assert len(small.stages) == 1 and small.stages[0].kind == "router"
    assert small.n <= 7          # halving stops once n // 2 < 4
    assert small.lanes == 1 and small.source == "plain"
    assert small.ii == 1 and small.start_delay == 0
    # local minimality: every further reduction breaks the predicate
    from repro.designs.generate import _reductions
    assert all(not still_fails(r) for r in _reductions(small))


def test_shrink_driver_preserves_failure_kind():
    """The CLI's shrink predicate only accepts reductions reproducing
    the ORIGINAL (kind, backend) — a reduction that fails differently is
    rejected, so corpus entries guard the observed disagreement."""
    import repro.launch.fuzz as fz

    spec = spec_from_seed(77, quick=True)
    calls = []

    def fake_fuzz_one(cand, backends, n_random=4):
        calls.append(cand)
        # every reduction of the original spec "fails", but with a
        # DIFFERENT kind -> the shrinker must keep the original spec
        kind = "latency" if cand == spec else "deadlock"
        return [fz.Mismatch(cand, kind, "worklist", None, "synthetic")], 1

    orig = fz.fuzz_one
    fz.fuzz_one = fake_fuzz_one
    try:
        small = fz._shrunk(spec, ["worklist"], 3,
                           kind="latency", backend="worklist")
    finally:
        fz.fuzz_one = orig
    assert small == spec and len(calls) > 1


def test_committed_corpus_replays_clean():
    """Every committed seed-corpus spec (prior shrinks) still passes the
    full differential check — these are the fuzzer's regression tests."""
    paths = sorted(glob.glob(os.path.join(CORPUS_DIR, "*.json")))
    assert paths, "seed corpus is empty — tests/fuzz_corpus/*.json missing"
    for path in paths:
        with open(path) as f:
            entry = json.load(f)
        spec = DesignSpec.from_json(entry["spec"])
        mism, _ = fuzz_one(spec, ["worklist"], n_random=3)
        assert not mism, (
            f"corpus regression {os.path.basename(path)}: "
            f"{mism[0].kind}: {mism[0].detail}")


def test_generated_designs_exercise_deadlocks():
    """The generator is not trivially safe: across a seed range, the
    all-1 corner deadlocks for a healthy fraction of designs (otherwise
    the deadlock-verdict half of the differential suite tests nothing)."""
    from repro.core.oracle import simulate
    n_dead = 0
    for seed in range(30):
        gen = generate_design(seed, quick=True)
        r = simulate(gen.design, np.ones(gen.design.n_fifos, dtype=int))
        n_dead += bool(r.deadlocked)
    assert n_dead >= 5, f"only {n_dead}/30 all-1 corners deadlock"
