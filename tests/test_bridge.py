"""FIFOAdvisor <-> pipeline-parallel bridge (DESIGN.md §5)."""


from repro.core import FifoAdvisor
from repro.core.bridge import pipeline_design, stages_from_layer_cost
from repro.core.oracle import simulate
from repro.core.tracer import collect_trace


def test_pipeline_design_traces_and_runs():
    S, M = 4, 8
    stages = stages_from_layer_cost(S, layers_per_stage=2,
                                    cycles_per_layer=10)
    d = pipeline_design(stages, n_microbatches=M)
    tr = collect_trace(d)
    # per microbatch: fwd (S-1 act reads + S stash writes + S-1 act writes)
    #               + bwd (S-1 grad reads + S stash reads + S-1 grad writes)
    assert tr.n_events == M * (6 * S - 4)
    r = simulate(d, [M] * d.n_fifos)
    assert not r.deadlocked


def test_deeper_queues_reduce_bubble_latency():
    """The pipeline trade-off the bridge exposes: more in-flight
    microbatches (deeper act queues) => lower makespan, until saturation."""
    stages = stages_from_layer_cost(
        4, 2, 10, imbalance=[1.0, 2.0, 1.0, 0.5])
    d = pipeline_design(stages, n_microbatches=16)
    shallow = simulate(d, [1] * d.n_fifos)
    deep = simulate(d, [16] * d.n_fifos)
    assert not shallow.deadlocked and not deep.deadlocked
    assert deep.latency < shallow.latency


def test_advisor_finds_pipeline_frontier():
    stages = stages_from_layer_cost(
        4, 2, 12, imbalance=[1.0, 1.5, 0.75, 1.0])
    d = pipeline_design(stages, n_microbatches=12)
    adv = FifoAdvisor(d)
    r = adv.run("grouped_sa", budget=200, seed=0)
    pts = r.frontier_points
    assert pts.shape[0] >= 1
    # the frontier spans a real trade-off (not a single point) for an
    # imbalanced pipeline
    if pts.shape[0] > 1:
        assert pts[:, 0].min() < pts[:, 0].max()
