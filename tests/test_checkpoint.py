"""Checkpointing: round-trip, atomic commit, pruning, async, resume."""


import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.train import checkpoint as ck


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (4, 8)),
                       "b": jnp.zeros((8,))},
            "opt": {"m": {"w": jnp.ones((4, 8)) * 2, "b": jnp.ones((8,))},
                    "step": jnp.int32(7)}}


def test_round_trip(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 3, t)
    assert ck.latest_step(str(tmp_path)) == 3
    r = ck.restore(str(tmp_path), 3, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_prune_keeps_latest(tmp_path):
    t = _tree()
    for s in [1, 2, 3, 4, 5]:
        ck.save(str(tmp_path), s, t, keep=2)
    assert ck.all_steps(str(tmp_path)) == [4, 5]


def test_half_written_checkpoint_is_invisible(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 1, t)
    # simulate a preempted save: tmp dir exists, no manifest committed
    crash = tmp_path / "step_00000002.tmp"
    crash.mkdir()
    (crash / "leaf_00000.npy").write_bytes(b"garbage")
    assert ck.latest_step(str(tmp_path)) == 1
    # and a directory without manifest is ignored too
    bad = tmp_path / "step_00000003"
    bad.mkdir()
    assert ck.latest_step(str(tmp_path)) == 1


def test_restore_missing_leaf_raises(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 1, t)
    target = dict(t)
    target["extra"] = jnp.zeros((2,))
    with pytest.raises(KeyError):
        ck.restore(str(tmp_path), 1, target)


def test_async_checkpointer(tmp_path):
    t = _tree()
    saver = ck.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in [10, 20]:
        saver.save(s, t)
    saver.wait()
    assert ck.all_steps(str(tmp_path)) == [10, 20]


def test_train_resume_end_to_end(tmp_path):
    """Kill-and-resume: losses after resume continue from the checkpoint
    (deterministic data ⇒ the resumed run matches an uninterrupted one)."""
    from repro.launch.train import main
    args = ["--arch", "qwen2-1.5b", "--steps", "6", "--batch", "2",
            "--seq", "32", "--ckpt", str(tmp_path), "--save-every", "3",
            "--log-every", "100"]
    out1 = main(args)                     # runs 0..5, saves at 3 and 6
    # second invocation: nothing left to do (resumes at 6)
    out2 = main(args)
    assert out2["steps"] == 0
    # fresh run to step 3 then resumed to 6 matches a straight-through run
    out3 = main(["--arch", "qwen2-1.5b", "--steps", "3", "--batch", "2",
                 "--seq", "32", "--ckpt", str(tmp_path / "b"),
                 "--save-every", "3", "--log-every", "100"])
    out4 = main(["--arch", "qwen2-1.5b", "--steps", "6", "--batch", "2",
                 "--seq", "32", "--ckpt", str(tmp_path / "b"),
                 "--save-every", "3", "--log-every", "100"])
    # bitwise equality is not guaranteed on the CPU backend (thread-pool
    # reduction order varies under load); the runs must agree closely
    assert abs(out4["last_loss"] - out1["last_loss"]) < 5e-3
