"""Device-mesh sharded evaluation: bit-identity, padding, and topology.

The sharded backend (``repro.core.backends.mesh``) must be
indistinguishable from the solo evaluators in everything but wall-clock:
identical latencies, BRAM, and deadlock verdicts across the worklist,
fixpoint, and Pallas backends on fuzz-corpus designs; exact under ragged
batches whose row count is not a shard multiple; and campaign/hetero
dispatch with a mesh must reproduce sequential frontiers bit for bit.

This module arms a 4-device host-platform CPU mesh at import (i.e. at
pytest collection, before any test computes through jax).  When the
environment already initialized jax on fewer devices — e.g. running this
file after a jax-touching REPL — the multi-device tests skip instead of
crashing; the CI mesh job runs the file under an 8-device XLA_FLAGS
anyway.
"""

import glob
import json
import os

import numpy as np
import pytest

from repro.core.config import EvalConfig
from repro.launch.mesh import (device_grid, ensure_host_platform_devices,
                               make_campaign_mesh, make_eval_mesh)

# must happen at import time, before jax's backends initialize
ensure_host_platform_devices(4)

jax = pytest.importorskip("jax")

CORPUS_DIR = os.path.join(os.path.dirname(__file__), "fuzz_corpus")


def _need_devices(n: int):
    if jax.device_count() < n:
        pytest.skip(f"needs >= {n} devices "
                    f"(jax initialized with {jax.device_count()})")


def _corpus_graphs():
    from repro.core import build_simgraph
    from repro.designs.generate import DesignSpec, build_design
    graphs = []
    for path in sorted(glob.glob(os.path.join(CORPUS_DIR, "*.json"))):
        with open(path) as f:
            spec = DesignSpec.from_json(json.load(f)["spec"])
        gen = build_design(spec)
        graphs.append((os.path.basename(path), build_simgraph(gen.design)))
    assert graphs, "tests/fuzz_corpus/*.json missing"
    return graphs


def _configs(g, C, seed=0, lo=0.1):
    """Depth batch spanning feasible AND deadlock-prone corners."""
    rng = np.random.default_rng(seed)
    u = np.asarray(g.upper_bounds, dtype=np.int64)
    rows = [u, np.ones_like(u)]
    rows += [np.maximum(1, (u * rng.uniform(lo, 1.0, u.size))
                        .astype(np.int64)) for _ in range(C - 2)]
    return np.stack(rows[:C])


# ------------------------------------------------------------- identity
def test_sharded_matches_every_solo_backend_on_corpus():
    """mesh == worklist == fixpoint == pallas (latency, BRAM, deadlock)
    on every committed fuzz-corpus design."""
    _need_devices(4)
    from repro.core.simulate import BatchedEvaluator
    for name, g in _corpus_graphs():
        cfgs = _configs(g, 10, seed=hash(name) % 1000)
        ref = BatchedEvaluator(
            g, EvalConfig(backend="numpy", max_iters=128)).evaluate(cfgs)
        for backend, kw in [("jax", {}), ("pallas", {}),
                            ("mesh", {"shards": 4}),
                            ("mesh", {"shards": 2})]:
            got = BatchedEvaluator(
                g, EvalConfig(backend=backend, max_iters=128, **kw)
            ).evaluate(cfgs)
            for a, b in zip(ref, got):
                np.testing.assert_array_equal(
                    a, b, err_msg=f"{name}:{backend}:{kw}")


def test_deadlock_verdicts_identical_across_shard_counts():
    """mult_by_2(n) deadlocks iff depth(x) < n - 1; the sharded path
    must agree on both sides of the boundary at every shard count."""
    _need_devices(4)
    from repro.core import build_simgraph
    from repro.core.simulate import BatchedEvaluator
    from repro.designs.ddcf import mult_by_2
    g = build_simgraph(mult_by_2(16))
    cfgs = np.array([[14, 2], [15, 2], [16, 2], [2, 2], [13, 3]])
    expect_dead = np.array([True, False, False, True, True])
    for shards in (1, 2, 4):
        lat, _, dead = BatchedEvaluator(
            g, EvalConfig(backend="mesh", max_iters=64,
                          shards=shards)).evaluate(cfgs)
        np.testing.assert_array_equal(dead, expect_dead,
                                      err_msg=f"shards={shards}")
        assert (lat[dead] == -1).all()


def test_ragged_batches_pad_to_shard_multiples_exactly():
    """Row counts that are not shard multiples (including C=1 and C above
    a bucket boundary) are padded, evaluated, and sliced back exactly."""
    _need_devices(4)
    from repro.core import build_simgraph
    from repro.core.simulate import BatchedEvaluator
    from repro.designs import make_design
    g = build_simgraph(make_design("gemm"))
    solo = BatchedEvaluator(g, EvalConfig(backend="jax", max_iters=64))
    mesh = BatchedEvaluator(
        g, EvalConfig(backend="mesh", max_iters=64, shards=4))
    assert mesh.dispatch.shard_multiple == 4
    all_cfgs = _configs(g, 13, seed=7)
    for C in (1, 3, 5, 13):
        cfgs = all_cfgs[:C]
        ref = solo.evaluate(cfgs)
        got = mesh.evaluate(cfgs)
        for a, b in zip(ref, got):
            assert a.shape[0] == C
            np.testing.assert_array_equal(a, b, err_msg=f"C={C}")


def test_pallas_inner_kernel_shards_identically():
    """MeshBackend(inner="pallas") wraps the Pallas kernel in the same
    row partitioning and returns the solo kernel's raw triples verbatim
    — statuses included (UNRESOLVED rows stay UNRESOLVED)."""
    _need_devices(2)
    from repro.core import build_simgraph
    from repro.core.backends.mesh import MeshBackend
    from repro.core.backends.pallas import PallasBackend
    from repro.designs.ddcf import mult_by_2
    g = build_simgraph(mult_by_2(24))
    cfgs = _configs(g, 6, seed=3)
    solo = PallasBackend()
    solo.prepare(g)
    ref = solo.evaluate(cfgs)
    impl = MeshBackend(shards=2, inner="pallas")
    impl.prepare(g)
    got = impl.evaluate(cfgs)   # 6 rows: already a multiple of 2 shards
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a, b)


def test_fused_condensed_kernel_shards_identically():
    """MeshBackend(inner="pallas") prepared on a high-compression rung
    exposes the FUSED kernel (``fused_certificate``) sharded over the
    mesh; ``evaluate_certified`` — latency, BRAM, status, AND the
    on-device certificate mask — is bit-identical to the solo kernel
    across shard counts, ragged batches included."""
    _need_devices(4)
    from repro.core import build_simgraph
    from repro.core.backends.mesh import MeshBackend
    from repro.core.backends.pallas import PallasBackend
    from repro.core.condense import condense_auto
    from repro.designs import make_design
    g = build_simgraph(make_design("gemm"))
    cg = condense_auto(g)[0]          # the aggressive rung
    solo = PallasBackend()
    solo.prepare(cg)
    assert solo.fused_certificate
    cfgs = _configs(g, 9, seed=5, lo=0.4)
    ref = solo.evaluate_certified(cfgs)
    assert np.asarray(ref[3]).any(), "batch must certify some rows"
    for shards in (2, 4):
        impl = MeshBackend(shards=shards, inner="pallas")
        impl.prepare(cg)
        assert impl.fused_certificate
        for C in (1, 4, 9):           # ragged vs shard multiple
            got = impl.evaluate_certified(cfgs[:C])
            for a, b in zip((r[:C] for r in ref), got):
                np.testing.assert_array_equal(
                    a, b, err_msg=f"shards={shards} C={C}")


# ------------------------------------------------- campaign and service
def test_campaign_with_shards_matches_sequential():
    """Hetero campaign on a mesh reproduces per-task sequential
    frontiers and hypervolumes bit for bit."""
    _need_devices(4)
    from repro.core.advisor import FifoAdvisor
    from repro.core.campaign import Campaign, CampaignSpec
    from repro.designs import make_design
    spec = dict(designs=("gemm", "FeedForward"),
                optimizers=("grouped_random",), budget=30, seed=0)
    store = Campaign(CampaignSpec(**spec, hetero=True,
                                  eval=EvalConfig(shards=4))).run()
    for key in store.keys():
        dse = store[key]
        design, opt, _ = key.split(":")
        solo = FifoAdvisor(make_design(design)).run(
            optimizer=opt, budget=30, seed=0)
        pts, _ = solo.result.frontier()
        np.testing.assert_array_equal(dse.frontier_points, pts,
                                      err_msg=key)


def test_hetero_dispatcher_with_mesh_matches_per_design_worklists():
    """Sharded cross-design hetero dispatch == per-design worklists."""
    _need_devices(4)
    from repro.core import build_simgraph
    from repro.core.backends.dispatch import HeteroDispatcher
    from repro.core.simulate import BatchedEvaluator
    from repro.designs import make_design
    from repro.designs.ddcf import mult_by_2
    designs = {"m24": mult_by_2(24), "gemm": make_design("gemm")}
    graphs = {k: build_simgraph(d) for k, d in designs.items()}
    hd = HeteroDispatcher(graphs, shards=4)
    assert hd.shard_multiple == 4
    items = [(k, _configs(g, 5, seed=i))
             for i, (k, g) in enumerate(graphs.items())]
    results = hd.dispatch(items)
    for (k, cfgs), (lat, bram, dead) in zip(items, results):
        ref = BatchedEvaluator(
            graphs[k], EvalConfig(backend="numpy", max_iters=64)).evaluate(cfgs)
        np.testing.assert_array_equal(lat, ref[0], err_msg=k)
        np.testing.assert_array_equal(bram, ref[1], err_msg=k)
        np.testing.assert_array_equal(dead, ref[2], err_msg=k)


# ----------------------------------------------------- topology + wiring
def test_device_grid_factorizations():
    assert device_grid(1) == (1, 1)
    assert device_grid(8) == (2, 4)
    assert device_grid(16) == (4, 4)
    assert device_grid(7) == (1, 7)
    with pytest.raises(ValueError):
        device_grid(0)


def test_mesh_constructors_validate_device_count():
    """Requesting more shards than devices fails with a clear error
    naming the remedy, not a deep jax crash."""
    n = jax.device_count()
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_eval_mesh(n + 1)
    with pytest.raises(ValueError, match=f"needs {(n + 1) * 2} devices"):
        make_campaign_mesh(design_shards=2, eval_shards=n + 1)
    mesh = make_eval_mesh(None)
    assert mesh.axis_names == ("eval",)
    assert int(mesh.devices.size) == n


def test_spawn_preserves_mesh_and_calibration_lists_mesh():
    """spawn() clones (for condensation rungs) keep the device mesh, and
    auto-calibration races the mesh backend only on multi-device hosts."""
    _need_devices(2)
    from repro.core import build_simgraph
    from repro.core.backends.mesh import MeshBackend
    from repro.core.simulate import BatchedEvaluator
    from repro.designs.ddcf import mult_by_2
    impl = MeshBackend(shards=2)
    clone = impl.spawn()
    assert clone.mesh is impl.mesh and clone.inner == impl.inner
    g = build_simgraph(mult_by_2(24))
    ev = BatchedEvaluator(g, EvalConfig(backend="auto", max_iters=64))
    assert "mesh" in ev.calibration["probe_s"]
    assert ev.backend == min(ev.calibration["probe_s"],
                             key=ev.calibration["probe_s"].get)


def test_jit_cache_env_unset_is_inert(monkeypatch):
    """Without REPRO_JIT_CACHE_DIR, configure_jax touches nothing (and
    never imports jax on its own)."""
    from repro.core.backends import jaxcfg
    monkeypatch.delenv(jaxcfg.ENV_VAR, raising=False)
    assert jaxcfg.configure_jax(force=True) is False


def test_jit_cache_env_populates_cache_dir(tmp_path):
    """REPRO_JIT_CACHE_DIR=dir makes the first backend jit write
    persistent cache entries into dir.  Runs in a subprocess because
    jax's compilation cache binds its directory at the process's first
    compile — exactly the wiring (operands imports -> configure_jax)
    this guards."""
    import subprocess
    import sys
    from repro.core.backends import jaxcfg
    cache_dir = tmp_path / "jitcache"
    env = dict(os.environ, **{jaxcfg.ENV_VAR: str(cache_dir)})
    env["PYTHONPATH"] = os.pathsep.join(sys.path)
    code = (
        "import numpy as np\n"
        "from repro.core import EvalConfig, build_simgraph\n"
        "from repro.core.simulate import BatchedEvaluator\n"
        "from repro.designs.ddcf import mult_by_2\n"
        "g = build_simgraph(mult_by_2(8))\n"
        "ev = BatchedEvaluator(g, EvalConfig(backend='jax', max_iters=64))\n"
        "ev.evaluate(np.stack([g.upper_bounds] * 2))\n")
    subprocess.run([sys.executable, "-c", code], env=env, check=True,
                   capture_output=True, text=True)
    assert os.path.isdir(cache_dir)
    assert any("cache" in name for name in os.listdir(cache_dir)), \
        "backend jit wrote no persistent cache entries"
