"""Kernel-grade differential layer for the fused condensed Pallas kernel.

The fused kernel (:mod:`repro.kernels.fifo_eval.condensed`) evaluates the
condensed fixpoint AND the exactness certificate in one launch; its
output mask decides — on device — which rows the rung cascade accepts.
A wrong mask is silently wrong *results*, so this module pins it to the
host ground truth at the bit level:

* the kernel's certificate mask equals ``condense.verify_rows`` on every
  committed fuzz-corpus design and on fresh generator seeds
  (hypothesis-shim driven), at every condensation rung,
* rows that deadlock in the raw graph can NEVER certify,
* rows failing the aggressive rung produce identical final results
  through the cascade as forcing the safe rung / raw backstop directly,
* a fully-certifying batch is device-resident: exactly one dispatch and
  the host verifier provably never runs,
* everything runs under ``interpret=True`` (no TPU in CI); the interpret
  flag is parametrized so real hardware can exercise ``False``.

Integer-exactness makes every assertion ``assert_array_equal`` — never
allclose.
"""

import glob
import importlib
import json
import os

import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

jax = pytest.importorskip("jax")

import repro.core.backends.worklist as wl
from repro.core import build_simgraph
from repro.core.backends.base import CONVERGED, DEADLOCK
from repro.core.condense import condense_auto, verify_rows
from repro.core.config import EvalConfig
from repro.core.simulate import BatchedEvaluator
from repro.designs import make_design, mult_by_2
from repro.designs.generate import (DesignSpec, build_design,
                                    generate_design)
from repro.kernels.fifo_eval.ops import (DISPATCH_COUNTS,
                                         make_condensed_eval)

CORPUS_DIR = os.path.join(os.path.dirname(__file__), "fuzz_corpus")

# the `condense` *module* (the function re-export in repro.core shadows
# it on attribute access; needed to monkeypatch verify_rows below)
condense_mod = importlib.import_module("repro.core.condense")


def _probe_rows(g, n_random=4, seed=0):
    """all-1 / all-2 / upper-bound corners plus random rows in [1, u]."""
    rng = np.random.default_rng(seed)
    u = np.asarray(g.upper_bounds, dtype=np.int64)
    rows = [np.ones_like(u), np.full_like(u, 2), u.copy()]
    for _ in range(n_random):
        rows.append(rng.integers(1, u + 1))
    return np.stack(rows).astype(np.int32)


def _hot_rows(g, C, seed=0):
    """Feasible-leaning rows (the cascade's in-box hot path)."""
    rng = np.random.default_rng(seed)
    u = np.asarray(g.upper_bounds, dtype=np.int64)
    return np.stack([np.maximum(
        2, (u * rng.uniform(0.5, 1.0, g.n_fifos)).astype(int))
        for _ in range(C)]).astype(np.int32)


def _assert_kernel_cert_matches_verify_rows(g, rows, interpret=True):
    """For every rung with expressible certificate tables: the kernel's
    on-device mask == CONVERGED & host ``verify_rows``, bit for bit."""
    n_checked = 0
    for cg in condense_auto(g):
        fused = make_condensed_eval(cg, interpret=interpret,
                                    max_iters=64, with_times=True)
        if fused is None:
            continue                  # no cert tables -> host verifier
        lat, bram, status, cert, times = (np.asarray(x)
                                          for x in fused(rows))
        t_int = np.asarray(np.rint(times), dtype=np.int64)
        expected = np.zeros(rows.shape[0], dtype=bool)
        conv = status == CONVERGED
        if conv.any():
            expected[conv] = verify_rows(cg, rows[conv].astype(np.int64),
                                         t_int[conv])
        np.testing.assert_array_equal(np.asarray(cert, bool), expected)
        # certified rows really are the raw least fixpoint
        for i in np.flatnonzero(cert):
            raw = wl.solve(g, rows[i].astype(np.int64))
            assert not raw.deadlocked
            assert int(lat[i]) == raw.latency
        n_checked += 1
    return n_checked


# ------------------------------------------------ mask == verify_rows
def test_kernel_cert_equals_verify_rows_on_corpus():
    """Every committed fuzz-corpus design, every rung: the fused mask is
    bit-identical to the host certificate."""
    paths = sorted(glob.glob(os.path.join(CORPUS_DIR, "*.json")))
    assert paths, "tests/fuzz_corpus/*.json missing"
    n_rungs = 0
    for path in paths:
        with open(path) as f:
            spec = DesignSpec.from_json(json.load(f)["spec"])
        g = build_simgraph(build_design(spec).design)
        n_rungs += _assert_kernel_cert_matches_verify_rows(
            g, _probe_rows(g, n_random=3))
    # at least one corpus design must actually exercise the kernel path
    assert n_rungs > 0


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=3000))
def test_kernel_cert_equals_verify_rows_fresh_seeds(seed):
    """Fresh generator seeds (hypothesis-shim driven): same bit-for-bit
    mask identity on arbitrary quick designs."""
    gen = generate_design(seed, quick=True)
    g = build_simgraph(gen.design)
    _assert_kernel_cert_matches_verify_rows(
        g, _probe_rows(g, n_random=2, seed=seed))


def test_kernel_cert_matches_on_benchmark_designs():
    """The paper's benchmark designs (the rungs auto-calibration races)
    hold the same identity on the differential row set + hot rows."""
    for name in ["gemm", "FeedForward"]:
        g = build_simgraph(make_design(name))
        rows = np.concatenate([_probe_rows(g, n_random=2),
                               _hot_rows(g, 6, seed=1)])
        assert _assert_kernel_cert_matches_verify_rows(g, rows) > 0


# ----------------------------------------------- deadlock soundness
@pytest.mark.parametrize("factory", [
    lambda: mult_by_2(24),
    lambda: make_design("k15mmtree"),
])
def test_deadlocked_rows_never_certify(factory):
    """A row that deadlocks in the RAW graph can never leave the kernel
    with a certificate: either the condensed solve deadlocks too (status
    DEADLOCK, cert forced off) or the certificate check fails."""
    g = build_simgraph(factory())
    rows = _probe_rows(g, n_random=4, seed=2)
    raw_dead = np.array([wl.solve(g, r.astype(np.int64)).deadlocked
                         for r in rows])
    assert raw_dead.any(), "probe rows must include deadlocks"
    for cg in condense_auto(g):
        fused = make_condensed_eval(cg, max_iters=64)
        if fused is None:
            continue
        _, _, status, cert = (np.asarray(x) for x in fused(rows))
        assert not (np.asarray(cert, bool) & raw_dead).any()
        # and DEADLOCK status always implies no certificate
        assert not (np.asarray(cert, bool)
                    & (status == DEADLOCK)).any()


# ------------------------------------------- cascade escalation paths
def test_cascade_escalation_identical_to_forced_rungs():
    """Rows that fail the aggressive rung must come out of the full
    cascade exactly as if the safe rung / raw backstop were forced
    directly — and everything equals the numpy ground truth."""
    g = build_simgraph(make_design("FeedForward"))
    rows = np.concatenate([_probe_rows(g, n_random=3),
                           _hot_rows(g, 8, seed=3)])
    rungs = condense_auto(g)
    assert len(rungs) >= 2
    ref = BatchedEvaluator(
        g, EvalConfig(backend="numpy", max_iters=64,
                      condense=None)).evaluate(rows)
    full = BatchedEvaluator(
        g, EvalConfig(backend="pallas", max_iters=64))
    got_full = full.evaluate(rows)
    # the aggressive rung must actually reject some probe rows, or the
    # escalation path under test is vacuous
    assert full.stats.n_cond_fail > 0
    for forced_rungs in ([rungs[-1]], []):      # safe only, raw only
        ev = BatchedEvaluator(
            g, EvalConfig(backend="pallas", max_iters=64),
            rungs=forced_rungs)
        got = ev.evaluate(rows)
        for a, b, c in zip(ref, got_full, got):
            np.testing.assert_array_equal(a, b)
            np.testing.assert_array_equal(a, c)


# ------------------------------------------------- device residency
def test_fully_certifying_batch_is_device_resident(monkeypatch):
    """When every row certifies on the aggressive rung, the whole batch
    is ONE fused dispatch: no scan/batched dispatches, and the host
    verifier provably never runs (it is patched to raise)."""
    g = build_simgraph(make_design("gemm"))
    rows = _hot_rows(g, 8, seed=0)
    expected = BatchedEvaluator(
        g, EvalConfig(backend="numpy", max_iters=64,
                      condense=None)).evaluate(rows)
    ev = BatchedEvaluator(g, EvalConfig(backend="pallas", max_iters=64))
    assert any(impl.fused_certificate for _, impl in ev._cascade.rungs)
    ev.evaluate(rows)                 # warm-up: jit compile + caches

    def _boom(*a, **k):
        raise AssertionError("host verify_rows ran on the fused path")
    monkeypatch.setattr(condense_mod, "verify_rows", _boom)
    DISPATCH_COUNTS.clear()
    got = ev.evaluate(rows)
    assert dict(DISPATCH_COUNTS) == {"condensed": 1}, (
        f"expected one fused dispatch, got {dict(DISPATCH_COUNTS)}")
    for a, b in zip(expected, got):
        np.testing.assert_array_equal(a, b)


# ------------------------------------------------- interpret flag
@pytest.mark.parametrize("interpret", [
    True,
    pytest.param(False, marks=pytest.mark.skipif(
        jax.default_backend() == "cpu",
        reason="interpret=False needs a real TPU/accelerator")),
])
def test_kernel_runs_under_interpret_flag(interpret):
    """The kernel is validated in interpret mode on CPU (the CI
    environment has no TPU); on real hardware the same test body runs
    compiled.  docs/performance.md documents the flag."""
    g = build_simgraph(make_design("gemm"))
    _assert_kernel_cert_matches_verify_rows(
        g, _probe_rows(g, n_random=3), interpret=interpret)
