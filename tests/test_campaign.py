"""Campaign engine: stepwise equivalence, scheduling, checkpoint/resume.

The load-bearing invariants:

* the stepwise ``propose()/observe()`` protocol driven by an external
  scheduler produces byte-identical histories to the blocking ``run()``
  for EVERY registered optimizer;
* a campaign (any routing mode) produces byte-identical per-task
  frontiers to the sequential ``FifoAdvisor.run()`` loop;
* killing a campaign mid-run and resuming from its checkpoint reproduces
  byte-identical frontiers and hypervolumes to an uninterrupted run
  (the seeded RNG state round-trips through the checkpoint — replay
  verifies the bit-state and raises on drift);
* the cross-design hetero dispatch agrees exactly with the per-design
  worklist.
"""

import numpy as np
import pytest

from repro.core import FifoAdvisor
from repro.core.campaign import (Campaign, CampaignSpec, CheckpointMismatch,
                                 load_checkpoint)
from repro.core.optimizers import OPTIMIZERS
from repro.designs import make_design

DESIGN = "gemm"
BUDGET = 80


@pytest.mark.parametrize("opt", sorted(OPTIMIZERS))
def test_stepwise_equals_blocking_run(opt):
    """Scheduler-style stepping == legacy blocking run, per optimizer."""
    d = make_design(DESIGN)
    adv_a = FifoAdvisor(d)
    blocking = adv_a.run(opt, budget=BUDGET, seed=3)

    adv_b = FifoAdvisor(d)
    ctx = adv_b.make_context(seed=3)
    stepper = OPTIMIZERS[opt](ctx, budget=BUDGET)
    while True:
        req = stepper.propose()
        if req is None:
            break
        # the campaign scheduler's routing: cache lookup, evaluate the
        # misses, record history/budget, observe
        lat, bram, dead, miss = ctx.cache.lookup(req.depths)
        rows = np.flatnonzero(miss)
        if rows.size:
            if req.base is not None and adv_b.evaluator.prefer_incremental:
                l, b, dd = adv_b.evaluator.evaluate_incremental(
                    req.base[rows], req.depths[rows])
            else:
                l, b, dd = adv_b.evaluator.evaluate(req.depths[rows])
            lat[rows], bram[rows], dead[rows] = l, b, dd
            ctx.cache.insert(req.depths[rows], l, b, dd)
        ctx.record(req.depths, lat, bram, dead, rows.size)
        stepper.observe(lat, bram, dead)
    stepwise = ctx.result(opt, 0.0)

    assert np.array_equal(blocking.result.configs, stepwise.configs)
    assert np.array_equal(blocking.result.latency, stepwise.latency)
    assert np.array_equal(blocking.result.bram, stepwise.bram)
    assert np.array_equal(blocking.result.deadlock, stepwise.deadlock)
    assert blocking.result.n_evals == stepwise.n_evals
    assert np.array_equal(blocking.frontier_points, stepwise.frontier()[0])


def _spec(**kw):
    base = dict(designs=("gemm", "FeedForward"),
                optimizers=("grouped_sa", "grouped_random"),
                budget=60, seed=0, workers=0)
    base.update(kw)
    return CampaignSpec(**base)


def test_campaign_matches_sequential_loop():
    store = Campaign(_spec()).run()
    for d in ("gemm", "FeedForward"):
        adv = FifoAdvisor(make_design(d))
        for o in ("grouped_sa", "grouped_random"):
            ref = adv.run(o, budget=60, seed=0)
            dse = store[f"{d}:{o}:s0"]
            assert np.array_equal(dse.frontier_points, ref.frontier_points)
            assert dse.hypervolume() == ref.hypervolume()
            assert np.array_equal(dse.result.configs, ref.result.configs)


def test_campaign_pool_matches_inline():
    import multiprocessing as mp

    spec = _spec(designs=("gemm",), budget=40)
    inline = Campaign(spec).run()
    pooled = Campaign(_spec(designs=("gemm",), budget=40,
                            workers=1)).run()
    for k in inline.keys():
        assert np.array_equal(pooled[k].frontier_points,
                              inline[k].frontier_points)
        assert np.array_equal(pooled[k].result.latency,
                              inline[k].result.latency)
    # run() closes the pool on exit; no worker may outlive it
    assert mp.active_children() == []


def test_checkpoint_resume_byte_identical(tmp_path):
    """Kill mid-run; resume must equal the uninterrupted run exactly."""
    spec = _spec(checkpoint_every=2)
    uninterrupted = Campaign(spec).run()

    path = str(tmp_path / "camp.npz")
    camp = Campaign(spec, checkpoint_path=path)
    camp.run(max_rounds=3)          # simulated kill
    assert not camp.finished

    resumed = Campaign.resume(path)
    # replay restored some finished work and the mid-flight generators
    store = resumed.run()
    assert resumed.finished
    for k in uninterrupted.keys():
        a, b = store[k], uninterrupted[k]
        assert np.array_equal(a.frontier_points, b.frontier_points)
        assert a.hypervolume() == b.hypervolume()
        assert np.array_equal(a.result.configs, b.result.configs)
        assert np.array_equal(a.result.latency, b.result.latency)
        assert a.result.n_evals == b.result.n_evals


def test_checkpoint_rng_state_roundtrip(tmp_path):
    """The checkpointed RNG bit-state must match the replayed one."""
    path = str(tmp_path / "camp.npz")
    camp = Campaign(_spec(designs=("gemm",), checkpoint_every=1),
                    checkpoint_path=path)
    camp.run(max_rounds=2)
    data = load_checkpoint(path)
    states = [t["rng_state"] for t in data["tasks"]]
    assert all(s["bit_generator"] == "PCG64" for s in states)
    resumed = Campaign.resume(path)     # raises CheckpointMismatch on drift
    for task, saved in zip(resumed.tasks, states):
        assert task.ctx.rng.bit_generator.state == saved


def test_checkpoint_tamper_detected(tmp_path):
    path = str(tmp_path / "camp.npz")
    camp = Campaign(_spec(designs=("gemm",), checkpoint_every=1),
                    checkpoint_path=path)
    camp.run(max_rounds=2)
    data = np.load(path, allow_pickle=False)
    arrays = {k: data[k].copy() for k in data.files}
    arrays["t0_configs"][0, 0] += 1      # corrupt the recorded history
    np.savez_compressed(path, **arrays)
    with pytest.raises(CheckpointMismatch):
        Campaign.resume(path)


def test_hetero_dispatch_matches_worklist():
    from repro.core.backends import DEADLOCK, HeteroDispatcher
    from repro.core.simgraph import build_simgraph
    from repro.core.tracer import collect_trace
    from repro.designs.ddcf import flowgnn_pna, mult_by_2

    designs = {"m2": mult_by_2(24), "pna": flowgnn_pna(n_nodes=12,
                                                       n_edges=30)}
    graphs = {k: build_simgraph(d, collect_trace(d))
              for k, d in designs.items()}
    disp = HeteroDispatcher(graphs, max_iters=64)
    rng = np.random.default_rng(11)
    items = []
    for k, g in graphs.items():
        u = g.upper_bounds
        m = np.concatenate([
            np.maximum(u, 2)[None, :],
            np.full((1, g.n_fifos), 2),
            np.maximum(2, (u * rng.uniform(0.1, 1.0, (6, g.n_fifos))
                           ).astype(np.int64))])
        items.append((k, m))
    for (k, m), (lat, bram, dead) in zip(items, disp.dispatch(items)):
        wlat, wbram, wstatus = disp.worklists[k].evaluate(m)
        wdead = wstatus == DEADLOCK
        assert np.array_equal(dead, wdead)
        assert np.array_equal(lat, np.where(wdead, -1, wlat))
        assert np.array_equal(bram, wbram)


def test_result_store_summary_roundtrip(tmp_path):
    store = Campaign(_spec(designs=("gemm",), budget=40)).run()
    out = store.summary()
    assert out["n_tasks"] == 2
    assert set(out["tasks"]) == {"gemm:grouped_sa:s0",
                                 "gemm:grouped_random:s0"}
    for entry in out["tasks"].values():
        assert entry["hypervolume"] > 0
        assert entry["frontier"]
    path = store.save_json(str(tmp_path / "store.json"))
    import json
    with open(path) as f:
        assert json.load(f)["n_tasks"] == 2
