"""Deterministic fault injection and the recovery machinery it exercises.

The contract under test (``docs/robustness.md``): a
:class:`~repro.core.faults.FaultPlan` is a seeded, replayable schedule
of crashes/hangs/corruptions consulted at fixed injection points, and
every recovery path it triggers — lane respawn + requeue, inline
escalation, E_TIMEOUT deadlines, reconnect replay, snapshot
quarantine — must leave results *bit-identical* to the fault-free run
(or, where work is genuinely lost, fail loudly with a stable error code
and keep the evaluated prefix).
"""

import multiprocessing as mp
import threading

import numpy as np
import pytest

from repro.core import EvalConfig, FifoAdvisor
from repro.core.campaign import Campaign, CampaignSpec
from repro.core.campaign.pool import MAX_OUTSTANDING, WorkerPool
from repro.core.faults import (FAULT_KINDS, Fault, FaultPlan,
                               InjectedFault, resolve_plan)
from repro.core.service import (AdvisoryService, DesignRegistry,
                                ProtocolHandler, SnapshotError,
                                load_snapshot, save_snapshot)
from repro.core.simulate import BatchedEvaluator
from repro.designs import make_design

BUDGET = 40


# ----------------------------------------------------------- plan basics
def test_fault_plan_json_roundtrip():
    plan = FaultPlan([Fault("crash_worker", at=1, lane=0),
                      Fault("hang_eval", at=2, target="gemm", value=0.5)])
    clone = FaultPlan.from_json(plan.to_json())
    assert clone.faults == plan.faults
    assert clone.n_fired == 0 and len(clone) == 2
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault("segfault_everything")
    assert set(f.kind for f in plan.faults) <= set(FAULT_KINDS)


def test_take_is_fire_once_with_wildcards():
    plan = FaultPlan([Fault("delay_dispatch", at=3, lane=-1, value=0.01),
                      Fault("crash_worker", at=0, lane=1),
                      Fault("hang_worker", at=2, lane=1, value=1.0)])
    # lane wildcard matches any caller lane; ``at`` always matches exactly
    assert plan.take("delay_dispatch", lane=7, at=0) is None
    f = plan.take("delay_dispatch", lane=7, at=3)
    assert f is not None and f.value == 0.01
    assert plan.take("delay_dispatch", lane=7, at=3) is None  # fire-once
    # worker payload ships only that lane's unfired worker faults
    assert plan.worker_payload(0) == []
    assert [d["kind"] for d in plan.worker_payload(1)] == [
        "crash_worker", "hang_worker"]
    # a revive consumes the smallest-``at`` worker fault for the lane,
    # so the replacement is shipped only the remaining schedule
    assert plan.consume_worker_fault(1).kind == "crash_worker"
    assert [d["kind"] for d in plan.worker_payload(1)] == ["hang_worker"]
    assert plan.consume_worker_fault(1).kind == "hang_worker"
    assert plan.consume_worker_fault(1) is None
    assert plan.n_fired == 3


def test_resolve_plan_config_beats_env(tmp_path):
    cfg_json = FaultPlan([Fault("crash_save", at=0)]).to_json()
    env_json = FaultPlan([Fault("drop_conn", at=5)]).to_json()
    plan = resolve_plan(EvalConfig(faults=cfg_json),
                        env={"REPRO_FAULTS": env_json})
    assert plan.faults[0].kind == "crash_save"
    # env alone: inline JSON, or @path to a plan file
    plan = resolve_plan(None, env={"REPRO_FAULTS": env_json})
    assert plan.faults[0].kind == "drop_conn"
    path = tmp_path / "plan.json"
    path.write_text(env_json)
    plan = resolve_plan(None, env={"REPRO_FAULTS": f"@{path}"})
    assert plan.faults[0].at == 5
    assert resolve_plan(None, env={}) is None


# ---------------------------------------------------- pool fault tolerance
@pytest.fixture(scope="module")
def gemm_jobs():
    """A gemm graph, a depth matrix, and the fault-free reference."""
    from repro.core.simgraph import build_simgraph
    from repro.core.tracer import collect_trace

    d = make_design("gemm")
    g = build_simgraph(d, collect_trace(d))
    u = g.upper_bounds
    rng = np.random.default_rng(0)
    m = np.concatenate([
        np.maximum(u, 2)[None, :],
        np.full((1, g.n_fifos), 2),
        np.maximum(2, (u * rng.uniform(0.1, 1.0, (6, g.n_fifos))
                       ).astype(np.int64))])
    ref = BatchedEvaluator(
        g, EvalConfig(backend="numpy", max_iters=64)).evaluate(m)
    return g, m, ref


def _pool_jobs(m, n_lanes):
    chunks = np.array_split(m, 4, axis=0)
    return [(j % n_lanes, "gemm", c, None) for j, c in enumerate(chunks)]


def _concat(results):
    return tuple(np.concatenate([r[k] for r in results])
                 for k in range(3))


def test_pool_crash_respawn_bit_identical(gemm_jobs):
    g, m, ref = gemm_jobs
    plan = FaultPlan([Fault("crash_worker", at=0, lane=0),
                      Fault("crash_worker", at=0, lane=1)])
    with WorkerPool(2, max_iters=64, graphs={"gemm": g}, faults=plan,
                    recv_timeout_s=5.0) as pool:
        results = pool.run_jobs(_pool_jobs(m, 2))
        stats = dict(pool.stats)
    lat, bram, dead = _concat(results)
    assert np.array_equal(lat, ref[0])
    assert np.array_equal(bram, ref[1])
    assert np.array_equal(dead, ref[2])
    assert stats["respawns"] >= 2 and stats["requeued"] >= 2
    assert plan.all_fired
    assert mp.active_children() == []


def test_pool_hang_detected_and_requeued(gemm_jobs):
    g, m, ref = gemm_jobs
    # the lane sleeps well past the recv deadline: it must be declared
    # dead, replaced, and its job re-dispatched — never waited out
    plan = FaultPlan([Fault("hang_worker", at=0, lane=0, value=30.0)])
    with WorkerPool(1, max_iters=64, graphs={"gemm": g}, faults=plan,
                    recv_timeout_s=0.3) as pool:
        results = pool.run_jobs(_pool_jobs(m, 1))
        stats = dict(pool.stats)
    assert np.array_equal(_concat(results)[0], ref[0])
    assert stats["respawns"] >= 1 and stats["requeued"] >= 1
    assert mp.active_children() == []


def test_submit_backpressure_survives_lane_death(gemm_jobs):
    g, m, ref = gemm_jobs
    # lane 0 wedges on its FIRST job while submit() still has more than
    # MAX_OUTSTANDING jobs to ship: the backpressure wait fills the
    # lane's queue, times out, and recovers the lane MID-submit.  The
    # wait must then observe the recovered queue draining (regression:
    # it watched a stale deque that recovery had orphaned, looping on
    # recv-timeout -> respawn-healthy-lane forever).
    plan = FaultPlan([Fault("hang_worker", at=0, lane=0, value=30.0)])
    jobs = [(0, "gemm", m[i % len(m)][None, :], None)
            for i in range(MAX_OUTSTANDING + 4)]
    done = {}

    def run():
        with WorkerPool(1, max_iters=64, graphs={"gemm": g}, faults=plan,
                        recv_timeout_s=0.5) as pool:
            done["results"] = pool.run_jobs(jobs)
            done["stats"] = dict(pool.stats)

    t = threading.Thread(target=run, daemon=True)
    t.start()
    t.join(timeout=90)
    assert not t.is_alive(), \
        "submit() backpressure wait hung after a lane death"
    assert done["stats"]["respawns"] >= 1
    for i, (lat, bram, dead, _) in enumerate(done["results"]):
        assert lat[0] == ref[0][i % len(m)]
        assert bram[0] == ref[1][i % len(m)]
    assert mp.active_children() == []


def test_pool_inline_escalation_after_max_retries(gemm_jobs):
    g, m, ref = gemm_jobs
    # every incarnation of lane 0 dies on its first job: after
    # max_retries lanes have burned, the parent runs the job inline
    plan = FaultPlan([Fault("crash_worker", at=0, lane=0)] * 3)
    with WorkerPool(1, max_iters=64, graphs={"gemm": g}, faults=plan,
                    recv_timeout_s=5.0, max_retries=1) as pool:
        results = pool.run_jobs(_pool_jobs(m, 1))
        stats = dict(pool.stats)
    lat, bram, dead = _concat(results)
    assert np.array_equal(lat, ref[0])
    assert np.array_equal(dead, ref[2])
    assert stats["escalated"] >= 1
    assert mp.active_children() == []


def test_pool_close_escalates_on_wedged_worker(gemm_jobs):
    g, m, _ = gemm_jobs
    plan = FaultPlan([Fault("hang_worker", at=0, lane=0, value=60.0)])
    pool = WorkerPool(1, max_iters=64, graphs={"gemm": g}, faults=plan,
                      recv_timeout_s=30.0)
    pool.join_timeout_s = 0.2
    pool.submit(_pool_jobs(m, 1))   # lane is now asleep mid-"evaluation"
    pool.close()                    # join times out -> terminate -> kill
    assert mp.active_children() == []


def test_campaign_frontiers_identical_under_crashes():
    # two tasks, so one lands on lane 1 — the pool worker (lane 0 is
    # always the parent process itself)
    spec = dict(designs=("gemm",),
                optimizers=("grouped_sa", "grouped_random"),
                budget=BUDGET, seed=0)
    inline = Campaign(CampaignSpec(workers=0, **spec)).run()
    plan_json = FaultPlan([Fault("crash_worker", at=0)]).to_json()
    camp = Campaign(CampaignSpec(workers=1,
                                 eval=EvalConfig(faults=plan_json),
                                 **spec))
    chaotic = camp.run()
    for k in inline.keys():
        assert np.array_equal(chaotic[k].frontier_points,
                              inline[k].frontier_points)
        assert np.array_equal(chaotic[k].result.latency,
                              inline[k].result.latency)
    assert camp.pool_stats["respawns"] >= 1
    assert camp.faults.all_fired
    assert mp.active_children() == []


# ------------------------------------------------------ service deadlines
def test_deadline_times_out_victim_and_isolates_peer():
    plan = FaultPlan([Fault("hang_eval", at=1, target="gemm", value=0.2)])
    with AdvisoryService(faults=plan) as svc:
        victim = svc.open_session("gemm", optimizer="grouped_sa",
                                  budget=BUDGET, seed=0, deadline_s=0.05)
        peer = svc.open_session("FeedForward", optimizer="grouped_sa",
                                budget=BUDGET, seed=1)
        svc.run_until_idle()
        assert victim.state == "failed"
        assert victim.error_code == "E_TIMEOUT"
        # the hung round itself was absorbed before the deadline fired,
        # so the partial result is a clean prefix, not a torn round
        assert victim.rounds == 2
        assert victim.dse_result().frontier_points.shape[0] >= 1
        assert peer.state == "done"
        solo = FifoAdvisor(make_design("FeedForward")).run(
            "grouped_sa", budget=BUDGET, seed=1)
        assert np.array_equal(peer.dse_result().frontier_points,
                              solo.frontier_points)
    assert plan.all_fired


def test_timeout_surfaces_in_events_and_status():
    plan = FaultPlan([Fault("hang_eval", at=0, target="gemm", value=0.2)])
    with AdvisoryService(faults=plan) as svc:
        sess = svc.open_session("gemm", budget=BUDGET, seed=0,
                                deadline_s=0.05)
        svc.run_until_idle()
        events = sess.drain_events()
        assert events[-1]["event"] == "failed"
        assert events[-1]["code"] == "E_TIMEOUT"
        assert sess.status()["code"] == "E_TIMEOUT"


# ---------------------------------------------------- reconnect + replay
def test_attach_replays_exact_event_suffix():
    with AdvisoryService() as svc:
        handler = ProtocolHandler(svc)
        sess = svc.open_session("gemm", budget=BUDGET, seed=0,
                                request_id="open-77")
        svc.run_until_idle(max_rounds=2)
        seen = sess.drain_events()           # delivered, then "conn dies"
        last_seq = seen[-1]["seq"] if seen else -1
        # idempotent open: re-sending the same request id returns the
        # session it created, never a duplicate
        again = svc.open_session("gemm", budget=BUDGET, seed=0,
                                 request_id="open-77")
        assert again is sess
        svc.run_until_idle()
        out = handler.handle({"op": "attach", "session": sess.id,
                              "after_seq": last_seq})
        assert out["ok"] and out["replay_complete"]
        stream = seen + out["events"]
        # the stitched stream is the exact full history: contiguous
        # seqs from 0, no duplicates, terminal event last
        assert [e["seq"] for e in stream] == list(range(len(stream)))
        assert stream[-1]["event"] == "done"
        # nothing left queued: the replay consumed the undelivered tail
        assert sess.drain_events() == []
        # releasing the session prunes its idempotent-open entry (the
        # map must not grow with every open a long-lived server ever
        # honoured); a re-sent open for a released session opens fresh
        svc.release(sess.id)
        assert "open-77" not in svc._open_requests
        fresh = svc.open_session("gemm", budget=BUDGET, seed=0,
                                 request_id="open-77")
        assert fresh is not sess


# ------------------------------------------------- snapshot crash + torn
def _warm_registry(designs, budget=30):
    reg = DesignRegistry()
    runs = {}
    for name in designs:
        runs[name] = reg.register(name).run("grouped_sa", budget=budget,
                                            seed=0)
    return reg, runs


def test_crash_mid_save_preserves_previous_snapshot(tmp_path):
    reg, runs = _warm_registry(["gemm"])
    save_snapshot(reg, str(tmp_path))
    # a later save dies before writing any member: the published
    # snapshot must still strict-load, bit-identical
    for at in (0, 1):   # before member 0 / before the manifest replace
        with pytest.raises(InjectedFault):
            save_snapshot(reg, str(tmp_path),
                          faults=FaultPlan([Fault("crash_save", at=at)]))
    reg2 = load_snapshot(str(tmp_path), registry=DesignRegistry(),
                         strict=True)
    assert reg2.names() == ["gemm"]
    dse = reg2["gemm"].run("grouped_sa", budget=30, seed=0)
    assert dse.result.n_evals == 0          # pure restored-cache hits
    assert np.array_equal(dse.frontier_points,
                          runs["gemm"].frontier_points)


def test_torn_write_quarantines_only_the_damaged_design(tmp_path):
    reg, runs = _warm_registry(["FeedForward", "gemm"])
    victim = "FeedForward"
    idx = [n for n in reg.names()].index(victim)
    save_snapshot(reg, str(tmp_path), faults=FaultPlan(
        [Fault("corrupt_snapshot", at=idx, target=victim, value=100)]))
    with pytest.raises(SnapshotError, match="checksum"):
        load_snapshot(str(tmp_path), registry=DesignRegistry(),
                      strict=True)
    reg2 = load_snapshot(str(tmp_path), registry=DesignRegistry())
    rep = reg2.restore_report
    assert sorted(rep["quarantined"]) == [victim]
    assert "checksum" in rep["quarantined"][victim]
    assert rep["restored"] == ["gemm"]
    # the healthy design restored warm: same search, zero simulations
    dse = reg2["gemm"].run("grouped_sa", budget=30, seed=0)
    assert dse.result.n_evals == 0
    assert np.array_equal(dse.frontier_points,
                          runs["gemm"].frontier_points)
