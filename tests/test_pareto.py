"""Pareto utilities: property tests against brute force."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.pareto import (hypervolume_2d, pareto_front, pareto_mask,
                               select_alpha_point)


def _dominates(a, b):
    return (a[0] <= b[0] and a[1] <= b[1]) and (a[0] < b[0] or a[1] < b[1])


@given(st.lists(st.tuples(st.integers(0, 50), st.integers(0, 50)),
                min_size=1, max_size=60))
@settings(max_examples=200, deadline=None)
def test_pareto_mask_matches_bruteforce(pts):
    pts = np.asarray(pts, dtype=float)
    mask = pareto_mask(pts)
    for i in range(len(pts)):
        dominated = any(_dominates(pts[j], pts[i])
                        for j in range(len(pts)) if j != i)
        assert mask[i] == (not dominated), (i, pts)


@given(st.lists(st.tuples(st.integers(0, 30), st.integers(0, 30)),
                min_size=1, max_size=40))
@settings(max_examples=100, deadline=None)
def test_front_sorted_and_nondominated(pts):
    pts = np.asarray(pts, dtype=float)
    idx = pareto_front(pts)
    f = pts[idx]
    assert (np.diff(f[:, 0]) >= 0).all()
    for i in range(len(f)):
        for j in range(len(f)):
            if i != j:
                assert not _dominates(f[j], f[i])


def test_hypervolume_simple():
    pts = np.array([[1.0, 3.0], [2.0, 2.0], [3.0, 1.0]])
    hv = hypervolume_2d(pts, (4.0, 4.0))
    # rectangles: (4-1)*(4-3)=3 + (4-2)*(3-2)=2 + (4-3)*(2-1)=1
    assert hv == 6.0
    assert hypervolume_2d(pts, (1.0, 1.0)) == 0.0


def test_alpha_selection_prefers_latency_at_high_alpha():
    """High alpha weights the latency ratio -> picks the low-latency point;
    low alpha weights memory -> picks the low-BRAM point (paper §IV-B)."""
    pts = np.array([[100.0, 0.0], [50.0, 100.0]])
    base = (100.0, 100.0)
    hi = select_alpha_point(pts, base, alpha=0.99)
    lo = select_alpha_point(pts, base, alpha=0.01)
    assert pts[hi][0] <= pts[lo][0]
    assert pts[hi][1] >= pts[lo][1]
    assert hi != lo


@given(st.lists(st.tuples(st.integers(1, 99), st.integers(1, 99)),
                min_size=1, max_size=30),
       st.floats(0.01, 0.99))
@settings(max_examples=100, deadline=None)
def test_alpha_point_is_on_front(pts, alpha):
    pts = np.asarray(pts, dtype=float)
    sel = select_alpha_point(pts, (50.0, 50.0), alpha)
    assert sel in set(pareto_front(pts).tolist())
