"""Simulator cross-validation: DES oracle vs worklist vs JAX batched path.

The three evaluators share only the timing CONTRACT (DESIGN.md §2.1), not
code; equality across randomized designs and depth vectors is the
reproduction's Table-II-style internal accuracy check.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.design import Design
from repro.core.oracle import simulate
from repro.core.simgraph import DesignRuleError, build_simgraph
from repro.core.config import EvalConfig
from repro.core.simulate import BatchedEvaluator, evaluate_np
from repro.designs.builder import map_stage, producer, sink, streams
from repro.designs.ddcf import mult_by_2


# --------------------------------------------------------- random chains

@st.composite
def chain_design(draw):
    """Random producer -> k map stages -> sink chain; always sequentially
    executable, arbitrary rate mismatches."""
    count = draw(st.integers(4, 40))
    k = draw(st.integers(1, 4))
    lanes = draw(st.sampled_from([1, 2, 4]))
    d = Design("chain")
    cur = streams(d, "s0", lanes)
    producer(d, "prod", cur, [1.0] * count,
             ii=draw(st.integers(1, 3)),
             start_delay=draw(st.integers(0, 5)))
    for i in range(k):
        nxt = streams(d, f"s{i + 1}", lanes)
        map_stage(d, f"m{i}", cur, nxt, count,
                  ii=draw(st.integers(1, 3)),
                  extra_delay=draw(st.integers(0, 4)))
        cur = nxt
    sink(d, "sink", cur, count, ii=draw(st.integers(1, 3)))
    depths = [draw(st.integers(1, count + 2)) for _ in range(d.n_fifos)]
    return d, depths


@given(chain_design())
@settings(max_examples=40, deadline=None)
def test_oracle_equals_worklist_on_random_chains(dd):
    d, depths = dd
    g = build_simgraph(d)
    r = simulate(d, depths)
    lat, dead = evaluate_np(g, np.asarray(depths))
    assert dead == r.deadlocked
    if not dead:
        assert lat == r.latency


def test_jax_backend_equals_oracle_on_random_configs():
    rng = np.random.default_rng(0)
    d = mult_by_2(24)
    g = build_simgraph(d)
    ev = BatchedEvaluator(g, EvalConfig(backend="jax", max_iters=64))
    cfgs = np.stack([rng.integers(2, 30, size=2) for _ in range(32)])
    lat, bram, dead = ev.evaluate(cfgs)
    for i in range(32):
        r = simulate(d, cfgs[i])
        assert bool(dead[i]) == r.deadlocked
        if not r.deadlocked:
            assert int(lat[i]) == r.latency


def test_low_iteration_cap_falls_back_exactly():
    d = mult_by_2(24)
    g = build_simgraph(d)
    ev = BatchedEvaluator(g, EvalConfig(backend="jax", max_iters=3))
    lat, _, dead = ev.evaluate(np.array([[24, 2], [2, 2]]))
    assert ev.stats.n_fallbacks >= 1
    r0 = simulate(d, [24, 2])
    assert not dead[0] and int(lat[0]) == r0.latency
    assert bool(dead[1])


# ----------------------------------------------------- mult_by_2 theory

@given(n=st.integers(3, 40), dx=st.integers(1, 45), dy=st.integers(1, 6))
@settings(max_examples=60, deadline=None)
def test_mult_by_2_deadlock_closed_form(n, dx, dy):
    """Fig. 2 design deadlocks iff depth(x) < n - 1: the consumer reads
    exactly one x then blocks on y, which the producer emits only after
    all n x-writes."""
    d = mult_by_2(n)
    r = simulate(d, [dx, dy])
    assert r.deadlocked == (dx < n - 1)
    g = build_simgraph(d)
    lat, dead = evaluate_np(g, np.array([dx, dy]))
    assert dead == r.deadlocked


# ------------------------------------------------------- design rules

def test_multiple_readers_rejected():
    d = Design("bad")
    d.fifo("x")

    @d.task("w")
    def w(ctx):
        yield ctx.write("x", 1)
        yield ctx.write("x", 1)

    @d.task("r1")
    def r1(ctx):
        yield ctx.read("x")

    @d.task("r2")
    def r2(ctx):
        yield ctx.read("x")

    with pytest.raises(DesignRuleError):
        build_simgraph(d)


def test_structural_deadlock_unread_fifo():
    """A fifo with more writes than reads deadlocks iff the writer cannot
    park the surplus: depth >= n_writes - n_reads is required."""
    d = Design("leftover")
    d.fifo("x")

    @d.task("w")
    def w(ctx):
        for _ in range(6):
            yield ctx.write("x", 1)

    @d.task("r")
    def r(ctx):
        for _ in range(2):
            yield ctx.read("x")

    g = build_simgraph(d)
    assert evaluate_np(g, np.array([3]))[1] is True
    assert evaluate_np(g, np.array([4]))[1] is False
    assert simulate(d, [3]).deadlocked and not simulate(d, [4]).deadlocked
