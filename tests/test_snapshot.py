"""Warm-restart snapshots: bit-identity, integrity, and speed.

The format contract (core/service/snapshot.py): a restored advisor is
indistinguishable from the one that was saved in every observable —
frontiers, histories, baselines, certificates, cache contents.  A
member that fails an integrity check (checksum, missing file) is
quarantined — healthy designs restore warm, the damaged one re-traces
on first use — while ``strict=True`` and manifest-level problems
(version, config, unreadable manifest) raise SnapshotError instead of
loading approximately.  Crash-consistency and fault-injection coverage
lives in ``tests/test_faults.py``.
"""

import glob
import json
import os
import time

import numpy as np
import pytest

from repro.core import EvalConfig, FifoAdvisor
from repro.core.service import (AdvisoryService, DesignRegistry,
                                ProtocolHandler, SnapshotError,
                                load_snapshot, save_snapshot)
from repro.core.service.snapshot import MANIFEST, SNAPSHOT_VERSION
from repro.designs import make_design

DESIGN = "gemm"
BUDGET = 60


def warm_registry(config=None, designs=(DESIGN,), budget=BUDGET):
    """A registry whose advisors have run a search (cache + history)."""
    reg = DesignRegistry(config or EvalConfig())
    runs = {}
    for name in designs:
        adv = reg.register(name)
        runs[name] = adv.run("grouped_sa", budget=budget, seed=0)
    return reg, runs


# ------------------------------------------------------------ round trip
def test_restore_is_bit_identical_and_simulates_nothing(tmp_path):
    reg, runs = warm_registry()
    save_snapshot(reg, str(tmp_path))

    t0 = time.perf_counter()
    reg2 = load_snapshot(str(tmp_path))
    restore_s = time.perf_counter() - t0
    adv = reg2[DESIGN]

    # structural identity
    ref = reg[DESIGN]
    assert adv.config == ref.config
    assert np.array_equal(adv.graph.upper_bounds, ref.graph.upper_bounds)
    assert adv.baseline_max.latency == ref.baseline_max.latency
    assert adv.baseline_min.deadlocked == ref.baseline_min.deadlocked
    assert len(adv.cache) == len(ref.cache)

    # the warm-restart payoff: re-running the same search touches only
    # the restored cache — zero fresh simulations, identical trajectory
    dse = adv.run("grouped_sa", budget=BUDGET, seed=0)
    ref_dse = runs[DESIGN]
    assert dse.result.n_evals == 0
    assert np.array_equal(dse.result.configs, ref_dse.result.configs)
    assert np.array_equal(dse.result.latency, ref_dse.result.latency)
    assert np.array_equal(dse.frontier_points, ref_dse.frontier_points)
    assert dse.hypervolume() == ref_dse.hypervolume()

    # and it is fast: restoring skips tracing/condensation/simulation
    fresh = FifoAdvisor(make_design(DESIGN))
    assert restore_s < max(0.5, fresh.trace_time_s), (
        f"restore took {restore_s:.3f}s vs trace {fresh.trace_time_s:.3f}s")


def test_restore_preserves_certified_floor(tmp_path):
    cfg = EvalConfig(certified_floor=True)
    reg = DesignRegistry(cfg)
    reg.register("gemm")
    ref = reg["gemm"]
    ref.run("grouped_random", budget=30, seed=0)
    assert ref._certification is not None
    save_snapshot(reg, str(tmp_path))
    adv = load_snapshot(str(tmp_path))["gemm"]
    cert, ref_cert = adv._certification, ref._certification
    assert cert is not None
    assert np.array_equal(cert.depths, ref_cert.depths)
    assert cert.latency == ref_cert.latency
    assert cert.n_probes == ref_cert.n_probes


def test_snapshot_skips_custom_designs(tmp_path):
    from repro.core.design import Design
    d = Design("custom_inline")
    d.fifo("a", width=32)

    @d.task("src")
    def src(ctx):
        for i in range(8):
            yield ctx.delay(1)
            yield ctx.write("a", i)

    @d.task("sink")
    def sink(ctx):
        for _ in range(8):
            yield ctx.read("a")

    reg, _ = warm_registry()
    reg.register("custom_inline", d)
    manifest = save_snapshot(reg, str(tmp_path))
    assert manifest["skipped"] == ["custom_inline"]
    assert "custom_inline" not in manifest["designs"]
    reg2 = load_snapshot(str(tmp_path))
    assert reg2.names() == [DESIGN]


# ------------------------------------------------------------- integrity
def _member(tmp_path, name=DESIGN):
    """The content-addressed member file for one design."""
    hits = glob.glob(str(tmp_path / f"{name}.*.snap.npz"))
    assert len(hits) == 1, hits
    return hits[0]


def test_gc_spares_superseded_generation(tmp_path):
    def members():
        return {os.path.basename(p) for p
                in glob.glob(str(tmp_path / "*.snap.npz"))}

    reg, _ = warm_registry()
    save_snapshot(reg, str(tmp_path))
    gen1 = members()
    # fresh cache entries change the member content, so each save below
    # publishes under a new content-addressed name
    reg[DESIGN].run("grouped_sa", budget=10, seed=1)
    save_snapshot(reg, str(tmp_path))
    # the superseded generation survives one save, so a reader that
    # already loaded the previous manifest can finish its restore warm
    assert gen1 < members()
    reg[DESIGN].run("grouped_sa", budget=10, seed=2)
    save_snapshot(reg, str(tmp_path))
    assert gen1.isdisjoint(members())   # reclaimed by the *next* save
    load_snapshot(str(tmp_path), strict=True)


def test_tampered_snapshot_is_quarantined_and_strict_rejects(tmp_path):
    reg, _ = warm_registry()
    save_snapshot(reg, str(tmp_path))
    victim = _member(tmp_path)
    with open(victim, "r+b") as fh:
        blob = bytearray(fh.read())
        blob[len(blob) // 2] ^= 0xFF
        fh.seek(0)
        fh.write(bytes(blob))
    # strict mode refuses a tampered member outright
    with pytest.raises(SnapshotError, match="checksum"):
        load_snapshot(str(tmp_path), strict=True)
    # default mode quarantines the damaged design instead of failing
    reg2 = load_snapshot(str(tmp_path))
    rep = reg2.restore_report
    assert sorted(rep["quarantined"]) == [DESIGN]
    assert "checksum" in rep["quarantined"][DESIGN]
    assert rep["restored"] == []
    assert reg2.names() == []


def test_version_mismatch_is_rejected(tmp_path):
    reg, _ = warm_registry()
    save_snapshot(reg, str(tmp_path))
    mpath = tmp_path / MANIFEST
    manifest = json.loads(mpath.read_text())
    manifest["version"] = SNAPSHOT_VERSION + 1
    mpath.write_text(json.dumps(manifest))
    with pytest.raises(SnapshotError, match="version"):
        load_snapshot(str(tmp_path))


def test_missing_file_and_unreadable_manifest_rejected(tmp_path):
    reg, _ = warm_registry()
    save_snapshot(reg, str(tmp_path))
    os.remove(_member(tmp_path))
    with pytest.raises(SnapshotError, match="missing"):
        load_snapshot(str(tmp_path), strict=True)
    rep = load_snapshot(str(tmp_path)).restore_report
    assert "missing" in rep["quarantined"][DESIGN]
    # manifest-level problems always raise — there is nothing to salvage
    with pytest.raises(SnapshotError, match="manifest"):
        load_snapshot(str(tmp_path / "no_such_dir"))


def test_config_mismatch_is_rejected(tmp_path):
    reg, _ = warm_registry(EvalConfig(max_iters=64))
    save_snapshot(reg, str(tmp_path))
    other = DesignRegistry(EvalConfig(max_iters=128))
    with pytest.raises(SnapshotError, match="config"):
        load_snapshot(str(tmp_path), other)
    # matching registry adopts fine
    ok = DesignRegistry(EvalConfig(max_iters=64))
    load_snapshot(str(tmp_path), ok)
    assert ok.names() == [DESIGN]


# ----------------------------------------------------- protocol + service
def test_snapshot_op_and_warm_first_answer(tmp_path):
    """End-to-end through the protocol: a served session populates the
    registry, the ``snapshot`` op persists it, and a *restarted* service
    answers its first request from cache — warm and bit-identical."""
    svc = AdvisoryService()
    handler = ProtocolHandler(svc, snapshot_dir=str(tmp_path))
    opened = handler.handle({"op": "open", "design": DESIGN,
                             "optimizer": "grouped_sa", "budget": BUDGET})
    assert opened["ok"]
    handler.handle({"op": "run"})
    ref = handler.handle({"op": "result", "session": opened["session"]})
    snap = handler.handle({"op": "snapshot"})
    assert snap["ok"] and snap["designs"] == [DESIGN]
    svc.close()

    # "restart": fresh service, registry restored from disk
    t0 = time.perf_counter()
    reg = load_snapshot(str(tmp_path))
    svc2 = AdvisoryService(registry=reg)
    handler2 = ProtocolHandler(svc2)
    opened2 = handler2.handle({"op": "open", "design": DESIGN,
                               "optimizer": "grouped_sa",
                               "budget": BUDGET})
    handler2.handle({"op": "run"})
    res = handler2.handle({"op": "result", "session": opened2["session"]})
    first_answer_s = time.perf_counter() - t0
    assert res["result"]["frontier"] == ref["result"]["frontier"]
    assert res["result"]["n_evals"] == 0           # pure cache hits
    assert first_answer_s < 2.0, f"warm first answer {first_answer_s:.2f}s"
    svc2.close()
