"""BRAM model (Algorithm 1) unit + property tests."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.bram import (bram_count, bram_count_np, breakpoints,
                             breakpoints_brute, design_bram_np,
                             fifo_read_latency, is_srl)


def test_srl_region_zero():
    assert bram_count(2, 512) == 0          # depth <= 2
    assert bram_count(32, 32) == 0          # 1024 bits
    assert bram_count(1024, 1) == 0         # 1024 bits
    assert bram_count(33, 32) > 0


def test_known_values():
    # 1024 x 32b: two 1Kx18 BRAMs
    assert bram_count(1024, 32) == 2
    # 2048 x 32b: 2x(1Kx18 rows) + 1x(2Kx9) + remainder -> 4
    assert bram_count(2048, 32) == 4
    # one deep narrow fifo: 16K x 1b = one 16Kx1
    assert bram_count(16384, 1) == 1


def test_read_latency_model():
    assert fifo_read_latency(2, 512) == 1
    assert fifo_read_latency(8, 32) == 1        # 256 bits -> SRL
    assert fifo_read_latency(2048, 32) == 2     # BRAM


@given(d=st.integers(1, 50_000), w=st.integers(1, 256))
@settings(max_examples=300, deadline=None)
def test_nonnegative_and_srl_consistency(d, w):
    n = bram_count(d, w)
    assert n >= 0
    assert (n == 0) == is_srl(d, w)


@given(d=st.integers(2, 20_000), w=st.integers(1, 128))
@settings(max_examples=200, deadline=None)
def test_monotone_in_depth(d, w):
    assert bram_count(d + 1, w) >= bram_count(d, w)


@given(ds=st.lists(st.integers(1, 8192), min_size=1, max_size=8),
       ws=st.lists(st.integers(1, 128), min_size=8, max_size=8))
@settings(max_examples=100, deadline=None)
def test_vectorized_matches_scalar(ds, ws):
    ds = (ds * 8)[:8]
    got = bram_count_np(np.asarray(ds), np.asarray(ws))
    exp = np.asarray([bram_count(d, w) for d, w in zip(ds, ws)])
    np.testing.assert_array_equal(got, exp)
    np.testing.assert_array_equal(
        design_bram_np(np.asarray(ds)[None, :], ws), exp.sum())


@given(w=st.integers(1, 72), u=st.integers(2, 6000))
@settings(max_examples=60, deadline=None)
def test_breakpoints_match_bruteforce(w, u):
    got = breakpoints(w, u)
    exp = breakpoints_brute(w, u)
    np.testing.assert_array_equal(got, exp)


@given(w=st.integers(1, 72), u=st.integers(2, 6000))
@settings(max_examples=60, deadline=None)
def test_breakpoints_are_maximal(w, u):
    """Every breakpoint d (except u) satisfies bram(d+1) > bram(d):
    taking any larger depth with the same BRAM count is impossible."""
    for d in breakpoints(w, u):
        if d not in (2, u):
            assert bram_count(int(d) + 1, w) > bram_count(int(d), w)
