import os
import sys

# src-layout import path (tests run with or without installation)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no xla_force_host_platform_device_count here — smoke tests and
# benches must see the 1-device default; launch/dryrun.py (run as a
# subprocess) requests 512 host devices, and tests/test_mesh.py arms a
# 4-device mesh at its own import (skipping its multi-device tests when
# the environment got there first).

# Property tests use hypothesis; fall back to the vendored shim when the
# real package is not installed (some execution environments cannot pip
# install).  The real package always wins when present.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_shim
    _hypothesis_shim.install()
