"""Shim-vs-real parity smoke test for the vendored hypothesis stand-in.

The suite's property tests must collect and pass against EITHER the real
``hypothesis`` package or ``tests/_hypothesis_shim.py`` (conftest falls
back to the shim when the real package cannot be installed).  These
tests pin the shared surface: every strategy constructor the suite uses
must exist in *both* implementations and draw values of the agreed
shapes — so the shim cannot silently drift from the real API, and new
tests cannot accidentally use hypothesis features the shim lacks.
"""

import random

import hypothesis
import hypothesis.strategies as st
from hypothesis import given, settings

import _hypothesis_shim as shim

#: every strategy constructor repo tests are allowed to draw on
SHARED_SURFACE = ("integers", "floats", "lists", "tuples", "sampled_from",
                  "booleans", "just", "one_of", "composite")


def test_surface_present_in_active_hypothesis():
    """Whichever implementation is active exposes the shared surface."""
    for name in SHARED_SURFACE:
        assert hasattr(st, name), f"active hypothesis lacks st.{name}"
    assert callable(hypothesis.given)
    assert callable(hypothesis.settings)


def test_surface_present_in_shim():
    """The shim itself exposes the shared surface (even when the real
    package won the ``sys.modules`` race in this environment)."""
    for name in SHARED_SURFACE:
        assert hasattr(shim.strategies, name), f"shim lacks st.{name}"


def test_shim_draws_match_real_semantics():
    """Shim strategies draw values with the same types/ranges the real
    package guarantees for the same constructors."""
    rng = random.Random(1234)
    s = shim.strategies
    for _ in range(50):
        v = s.integers(min_value=-3, max_value=7).example(rng)
        assert isinstance(v, int) and -3 <= v <= 7
        b = s.booleans().example(rng)
        assert isinstance(b, bool)
        assert s.just("token").example(rng) == "token"
        u = s.one_of(s.just(0), s.integers(min_value=5,
                                           max_value=9)).example(rng)
        assert u == 0 or 5 <= u <= 9
        xs = s.lists(s.floats(min_value=0.0, max_value=1.0),
                     min_size=1, max_size=4).example(rng)
        assert 1 <= len(xs) <= 4 and all(0.0 <= x <= 1.0 for x in xs)
        t = s.tuples(s.booleans(), s.sampled_from(("a", "b"))).example(rng)
        assert isinstance(t, tuple) and t[1] in ("a", "b")


def test_shim_runs_deterministically():
    """Two @given runs of the same shim test see identical draws."""
    seen = []

    @shim.given(shim.strategies.integers(min_value=0, max_value=10 ** 6))
    def collect(v):
        seen.append(v)

    collect()
    first = list(seen)
    seen.clear()
    collect()
    assert seen == first and len(first) == shim.DEFAULT_MAX_EXAMPLES


@settings(max_examples=10, deadline=None)
@given(st.booleans(), st.one_of(st.just(-1), st.integers(min_value=0,
                                                         max_value=3)))
def test_new_strategies_drive_given(flag, v):
    """The new strategies compose with @given under either backend."""
    assert isinstance(flag, bool)
    assert v == -1 or 0 <= v <= 3
