"""Deadlock certification + blame unit tests.

`mult_by_2(n)` is the paper's Fig. 2 design: the producer fills stream x
with n items before touching y, while the consumer alternates x/y reads.
The analytically minimal deadlock-free sizing is therefore
``depth(x) = max(n - 1, 1)`` (x must buffer everything the consumer has
not yet drained while it waits for y's first element) and
``depth(y) = 1`` — knowable only at runtime, which is the paper's whole
argument.
"""

import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.core import EvalConfig, FifoAdvisor
from repro.core.deadlock import (certify_min_depths_oracle, deadlock_blame,
                                 extract_wait_graph)
from repro.core.oracle import simulate
from repro.designs.ddcf import flowgnn_pna, mult_by_2
from repro.designs.generate import generate_design


# ---------------------------------------------------------------------- certify

@pytest.mark.parametrize("n", [2, 3, 8, 17, 40, 64])
def test_mult_by_2_certified_depths_analytical(n):
    """Certified depths equal the analytically known n-dependent answer."""
    adv = FifoAdvisor(mult_by_2(n))
    got = adv.min_safe_depths()
    assert got.tolist() == [max(n - 1, 1), 1]
    # the oracle confirms the certificate...
    assert not simulate(adv.design, got).deadlocked
    # ...and coordinate minimality: one less anywhere deadlocks
    for f in range(got.shape[0]):
        lower = got.copy()
        if lower[f] > 1:
            lower[f] -= 1
            assert simulate(adv.design, lower).deadlocked


def test_certified_depths_monotone_in_n():
    """Bigger n never certifies smaller depths (monotone workload)."""
    prev = None
    for n in (4, 9, 16, 31, 48):
        d = FifoAdvisor(mult_by_2(n)).min_safe_depths()
        if prev is not None:
            assert (d >= prev).all(), (n, d, prev)
        prev = d


def test_fast_path_matches_oracle_bisection():
    """The solve_delta-driven certifier and the naive DES bisection land
    on identical vectors (same start, same order, same lattice point)."""
    for design in (mult_by_2(24), flowgnn_pna(n_nodes=24, n_edges=64)):
        adv = FifoAdvisor(design)
        fast = adv.min_safe_depths()
        naive = certify_min_depths_oracle(design)
        assert (fast == naive.depths).all()
        assert adv.certification.latency == naive.latency
        assert adv.certification.bram == naive.bram


def test_flowgnn_certified_confirmed_by_oracle():
    """Acceptance: the oracle confirms certification on the FlowGNN DDCF
    design, and lowering any certified-above-floor FIFO deadlocks."""
    design = flowgnn_pna()
    adv = FifoAdvisor(design)
    got = adv.min_safe_depths()
    assert not simulate(design, got).deadlocked
    above_floor = np.flatnonzero(got > 1)
    assert above_floor.size > 0       # the design has real sizing cliffs
    for f in above_floor[:3]:
        lower = got.copy()
        lower[f] -= 1
        assert simulate(design, lower).deadlocked


def test_certification_cached_on_advisor():
    adv = FifoAdvisor(mult_by_2(16))
    first = adv.min_safe_depths()
    probes = adv.certification.n_probes
    again = adv.min_safe_depths()
    assert (first == again).all()
    assert adv.certification.n_probes == probes     # no recompute
    first[0] = -1                                   # caller copies are safe
    assert adv.min_safe_depths()[0] != -1


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=3000))
def test_certified_depths_on_generated_designs(seed):
    """Property: on arbitrary generated designs the certified vector is
    oracle-confirmed deadlock-free and no single FIFO can go lower."""
    gen = generate_design(seed, quick=True)
    adv = FifoAdvisor(gen.design)
    got = adv.min_safe_depths()
    assert not simulate(gen.design, got).deadlocked, f"seed {seed}"
    above = np.flatnonzero(got > 1)
    for f in above[:2]:
        lower = got.copy()
        lower[f] -= 1
        assert simulate(gen.design, lower).deadlocked, \
            f"seed {seed}: fifo {f} not minimal"


def test_certified_floor_clamps_searches():
    """certified_floor=True: every sampled configuration is feasible, so
    a whole DSE run records zero deadlocked samples — including the
    Baseline-Min probe the annealing optimizers issue (it clamps to the
    certified floor)."""
    for optimizer in ("grouped_random", "grouped_sa", "greedy"):
        adv = FifoAdvisor(mult_by_2(24), EvalConfig(certified_floor=True))
        res = adv.run(optimizer, budget=60, seed=3)
        assert res.result.configs.shape[0] > 0
        assert not res.result.deadlock.any(), optimizer
        assert (res.result.configs
                >= adv.min_safe_depths()[None, :]).all(), optimizer
    # baseline objects follow the clamped probe and stay feasible
    assert not adv.baseline_min.deadlocked


def test_infeasible_start_raises():
    from repro.core.deadlock import certify_min_depths
    adv = FifoAdvisor(mult_by_2(16))
    with pytest.raises(ValueError):
        certify_min_depths(adv.graph, adv.evaluator,
                           upper=np.array([2, 2]))


def test_certified_floor_respects_user_upper_bounds():
    """Certification descends from explicit advisor upper bounds, so the
    certified floor can never exceed the search caps — and when no
    deadlock-free configuration exists under the caps, the advisor says
    so instead of silently sampling deadlocks."""
    caps = np.array([70, 3])
    adv = FifoAdvisor(mult_by_2(64), EvalConfig(certified_floor=True),
                      upper_bounds=caps)
    assert adv.min_safe_depths().tolist() == [63, 1]
    res = adv.run("grouped_random", budget=30, seed=0)
    assert not res.result.deadlock.any()
    assert (res.result.configs <= caps[None, :]).all()
    with pytest.raises(ValueError):
        FifoAdvisor(mult_by_2(64), EvalConfig(certified_floor=True),
                    upper_bounds=np.array([16, 16]))


def test_floor_above_start_respected():
    """Bugfix: a `lower` floor above the descent start used to leave the
    certified depth below the floor (the binary-search window [floor,
    start] was empty and the loop never ran)."""
    from repro.core.deadlock import certify_min_depths
    design = mult_by_2(16)
    adv = FifoAdvisor(design)
    assert adv.graph.max_occupancy.tolist() == [15, 2]
    for floor in ([40, 3], [40, 1], [8, 5]):
        lower = np.asarray(floor)
        res = certify_min_depths(adv.graph, adv.evaluator, cache=adv.cache,
                                 lower=lower)
        assert (res.depths >= lower).all(), floor
        naive = certify_min_depths_oracle(design, lower=lower)
        assert (res.depths == naive.depths).all(), floor
    # fully-floored coordinates pin exactly at the floor; free ones
    # still reach their conditional minimum
    res = certify_min_depths(adv.graph, adv.evaluator, cache=adv.cache,
                             lower=np.array([40, 1]))
    assert res.depths.tolist() == [40, 1]


def test_probe_count_is_cache_misses():
    """Bugfix: n_probes counted cache hits too; now it reports evaluator
    work (misses) and n_cache_hits the replays — a certification re-run
    against a warm cache is answered entirely by it."""
    from repro.core.deadlock import certify_min_depths
    adv = FifoAdvisor(mult_by_2(16))
    first = certify_min_depths(adv.graph, adv.evaluator, cache=adv.cache)
    assert first.n_probes > 0
    again = certify_min_depths(adv.graph, adv.evaluator, cache=adv.cache)
    assert again.n_probes == 0
    assert again.n_cache_hits == first.n_probes + first.n_cache_hits
    assert (again.depths == first.depths).all()


def test_fuzz_seed_range_validation():
    """Bugfix: empty/inverted --seeds ranges used to fuzz zero designs
    and exit 0 ("0 disagreements"); they must exit non-zero."""
    from repro.launch import fuzz
    assert fuzz.parse_seed_range("3") == range(3, 4)
    assert fuzz.parse_seed_range("0:5") == range(0, 5)
    for bad in ("5:5", "10:2", "abc", "1:z", ":"):
        with pytest.raises(ValueError):
            fuzz.parse_seed_range(bad)
    assert fuzz.main(["--seeds", "5:5", "--quick"]) == 2
    assert fuzz.main(["--seeds", "10:2", "--quick"]) == 2
    assert fuzz.main(["--seeds", "nope", "--quick"]) == 2


def test_fuzz_bounds_mode_cli():
    """--mode bounds runs the channel-bounds contract end to end and
    exits 0 on a clean range."""
    from repro.launch import fuzz
    assert fuzz.main(["--mode", "bounds", "--seeds", "0:4",
                      "--quick"]) == 0


# ---------------------------------------------------------------------- blame

def test_blame_names_exactly_the_cycle_fifos():
    """Undersized mult_by_2 deadlocks through the x/y cycle: producer
    blocked writing x (full), consumer blocked reading y (empty)."""
    assert deadlock_blame(mult_by_2(16), [2, 2]) == ["x", "y"]
    # x alone sized correctly -> no deadlock -> no blame
    assert deadlock_blame(mult_by_2(16), [15, 1]) == []


def test_wait_graph_structure():
    design = mult_by_2(12)
    r = simulate(design, [3, 3])
    assert r.deadlocked and r.blocked_ops
    wg = extract_wait_graph(design, r)
    cycles = wg.cycles()
    assert cycles == [["consumer", "producer"]]
    reasons = {(e.waiter, e.fifo): e.reason for e in wg.edges}
    assert reasons[("producer", "x")] == "full"
    assert reasons[("consumer", "y")] == "empty"
    text = wg.describe()
    assert "cycle:" in text and "producer" in text and "consumer" in text


def test_blame_on_flowgnn_cycle():
    """The FlowGNN engine deadlocks through the scatter -> feat_q ->
    node_loader -> deg/msg -> aggregator cycle when control queues are
    starved; the blame set must name only real FIFOs on that cycle."""
    design = flowgnn_pna(n_nodes=24, n_edges=64)
    depths = np.ones(design.n_fifos, dtype=np.int64)
    blame = deadlock_blame(design, depths)
    names = {f.name for f in design.fifos}
    assert blame and set(blame) <= names


def test_advisor_explain_deadlock():
    adv = FifoAdvisor(mult_by_2(10))
    wg = adv.explain_deadlock(np.array([2, 2]))
    assert wg.blame() == ["x", "y"]
    assert adv.explain_deadlock(adv.min_safe_depths()).blame() == []
