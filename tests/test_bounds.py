"""Analytical channel-bounds engine tests (core/bounds.py, docs/bounds.md).

The contract under test, for every design:

* **bracket** — ``lower <= certified <= upper`` per FIFO;
* **identity** — certification seeded with the bounds returns the exact
  vector unseeded certification returns;
* **exactness on affine designs** — static stages only: the analytical
  lower bounds ARE the certified depths, and seeded certification needs
  at most two evaluator probes (start check + shortcut).
"""

from collections import Counter

import numpy as np
import pytest

from repro.core import EvalConfig, FifoAdvisor
from repro.core.backends import ConfigCache
from repro.core.bounds import (DATA_DEPENDENT, INORDER_MATCHED,
                               INORDER_MISMATCHED, REORDER, channel_bounds)
from repro.core.deadlock import certify_min_depths, certify_min_depths_oracle
from repro.core.simgraph import build_simgraph
from repro.core.simulate import BatchedEvaluator
from repro.designs.ddcf import flowgnn_pna, mult_by_2
from repro.designs.generate import (DesignSpec, StageSpec, build_design,
                                    load_corpus_specs, spec_from_seed)
from repro.designs.streamhls import make_design
from repro.launch.fuzz import bounds_one

KINDS = {INORDER_MATCHED, INORDER_MISMATCHED, REORDER, DATA_DEPENDENT}


def _evaluator(g):
    return BatchedEvaluator(g, EvalConfig(backend="worklist", max_iters=64))


# ------------------------------------------------------------------ analytics

@pytest.mark.parametrize("n", [2, 8, 16, 40])
def test_mult_by_2_bounds_are_the_papers_answer(n):
    """The need-DP reproduces the paper's Fig. 2 analytical sizing
    ``[max(n-1, 1), 1]`` from the trace alone — and labels both channels
    data-dependent (closed-form only for this n)."""
    g = build_simgraph(mult_by_2(n))
    b = channel_bounds(g)
    assert b.lower.tolist() == [max(n - 1, 1), 1]
    assert (b.upper == np.maximum(g.max_occupancy, 1)).all()
    assert all(k == DATA_DEPENDENT for k in b.kinds)
    cert = certify_min_depths_oracle(mult_by_2(n))
    assert (cert.depths == b.lower).all()


def test_taxonomy_on_streamhls_designs():
    """Real affine designs hit every static class: atax is all
    rate-matched (every channel pinned at depth 1), gemm adds burst
    (rate-mismatched) channels, FeedForward's fork/join skip paths are
    reorder channels with positive slack."""
    atax = channel_bounds(build_simgraph(make_design("atax")))
    assert set(atax.kinds) == {INORDER_MATCHED}
    assert atax.pinned.all() and (atax.lower == 1).all()

    gemm = channel_bounds(build_simgraph(make_design("gemm")))
    kinds = Counter(gemm.kinds)
    assert kinds[INORDER_MATCHED] and kinds[INORDER_MISMATCHED]

    ff = channel_bounds(build_simgraph(make_design("FeedForward")))
    assert Counter(ff.kinds)[REORDER] > 0
    reorder = np.asarray([k == REORDER for k in ff.kinds])
    assert (ff.slack[reorder] > 0).all()
    assert (ff.slack[~reorder] == 0).all()


def test_bounds_invariants_and_views():
    g = build_simgraph(make_design("FeedForward"))
    b = channel_bounds(g)
    assert (1 <= b.lower).all() and (b.lower <= b.upper).all()
    assert (b.lower == 1 + np.minimum(b.slack, b.upper - 1)).all()
    assert (b.pinned == (b.lower == b.upper)).all()
    assert b.n_pinned == int(b.pinned.sum())
    d = b.to_dict()
    assert d["lower"] == b.lower.tolist() and d["n_pinned"] == b.n_pinned
    names = [f.name for f in g.design.fifos]
    table = b.describe(names)
    assert names[0] in table and REORDER in table


def test_ddcf_channels_flagged_via_task_metadata():
    """Any channel touched by a ``data_dependent`` task is labelled DDCF
    — the generated expand/router/phase motifs and the whole FlowGNN
    engine — while purely affine specs have none."""
    g = build_simgraph(flowgnn_pna(n_nodes=16, n_edges=32))
    assert all(k == DATA_DEPENDENT for k in channel_bounds(g).kinds)

    ddcf_spec = DesignSpec(seed=3, n=6, lanes=1, ii=1, start_delay=0,
                           source="plain",
                           stages=[StageSpec("expand", {"ii": 1})])
    assert not ddcf_spec.affine_only
    b = channel_bounds(build_simgraph(build_design(ddcf_spec).design))
    assert DATA_DEPENDENT in b.kinds

    affine_spec = DesignSpec(seed=3, n=6, lanes=1, ii=1, start_delay=0,
                             source="plain",
                             stages=[StageSpec("conv", {"taps": 3, "ii": 1})])
    assert affine_spec.affine_only
    b = channel_bounds(build_simgraph(build_design(affine_spec).design))
    assert DATA_DEPENDENT not in b.kinds
    assert set(b.kinds) <= KINDS


# ------------------------------------------------------- seeded certification

@pytest.mark.parametrize("name", ["gemm", "mvt", "k2mm"])
def test_seeded_certification_identity_and_probe_reduction(name):
    """bounds= seeding: identical certified vector, >=3x fewer evaluator
    probes (the acceptance gate benchmarks/bounds.py enforces suite-wide)."""
    g = build_simgraph(make_design(name))
    b = channel_bounds(g)
    plain = certify_min_depths(g, _evaluator(g), cache=ConfigCache(g.n_fifos))
    seeded = certify_min_depths(g, _evaluator(g), cache=ConfigCache(g.n_fifos),
                                bounds=b)
    assert (plain.depths == seeded.depths).all()
    assert (plain.latency, plain.bram) == (seeded.latency, seeded.bram)
    assert seeded.n_probes * 3 <= plain.n_probes
    assert seeded.n_probes <= 2     # shortcut: start check + floor probe


def test_seeded_oracle_matches_seeded_fast_path():
    design = mult_by_2(24)
    g = build_simgraph(design)
    b = channel_bounds(g)
    fast = certify_min_depths(g, _evaluator(g), bounds=b)
    naive = certify_min_depths_oracle(design, bounds=b)
    assert (fast.depths == naive.depths).all()
    assert naive.n_cache_hits == 0           # the oracle has no cache


def test_bounds_respect_user_caps_and_floors():
    """Analytical floors never raise certification above user `upper`
    caps (only an explicit `lower` may), and compose with user floors."""
    design = mult_by_2(64)
    g = build_simgraph(design)
    b = channel_bounds(g)
    caps = np.array([70, 3])
    res = certify_min_depths(g, _evaluator(g), upper=caps, bounds=b)
    assert res.depths.tolist() == [63, 1]
    assert (res.depths <= caps).all()
    res = certify_min_depths(g, _evaluator(g), lower=np.array([80, 2]),
                             bounds=b)
    assert res.depths.tolist() == [80, 2]
    with pytest.raises(ValueError):
        certify_min_depths(g, _evaluator(g), upper=np.array([4, 4]),
                           bounds=b)


def test_advisor_channel_bounds_and_grid_clamp():
    """FifoAdvisor exposes cached bounds; EvalConfig(channel_bounds=True)
    clamps every optimizer grid at the analytical lower bounds without
    changing the certified floor or frontier feasibility."""
    adv = FifoAdvisor(mult_by_2(24), EvalConfig(channel_bounds=True))
    b = adv.channel_bounds()
    assert b is adv.channel_bounds()                 # cached
    assert (adv.min_safe_depths() >= b.lower).all()
    assert (adv.min_safe_depths() <= b.upper).all()
    ctx = adv.make_context(seed=0)
    for f, cand in enumerate(ctx.candidates):
        assert cand.size and (cand >= min(int(b.lower[f]), int(cand[-1]))).all()
    res = adv.run("grouped_random", budget=40, seed=1)
    assert res.result.configs.shape[0] > 0
    # every sampled depth respects the analytical floor, so no sample
    # can deadlock through an analytically-undersized channel
    assert (res.result.configs >= np.minimum(
        b.lower, np.asarray([c[-1] for c in ctx.candidates]))[None, :]).all()


# ---------------------------------------------------------------- corpus sweep

def test_corpus_and_seed_sweep_bounds_contract():
    """The committed fuzz corpus plus fresh seeds all satisfy the bounds
    contract: bracket everywhere, seeded identity, and affine-only specs
    certified exactly and probe-free (via the CLI's own checker, so the
    CI bounds step tests the same code path)."""
    import glob
    specs = load_corpus_specs(sorted(glob.glob("tests/fuzz_corpus/*.json")))
    specs += [spec_from_seed(s, quick=True) for s in range(30)]
    assert any(s.affine_only for s in specs)
    assert any(not s.affine_only for s in specs)
    for spec in specs:
        mism, n_channels = bounds_one(spec)
        assert n_channels > 0
        assert not mism, (spec.seed, [m.detail for m in mism])
