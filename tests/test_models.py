"""Per-architecture smoke tests (deliverable f): reduced family-preserving
configs, one forward + one train step on CPU, asserting shapes + no NaNs;
plus decode-vs-full-forward consistency for each family.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_arch
from repro.models import params as pm
from repro.models.transformer import forward, model_specs
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.steps import (make_decode_step, make_prefill_step,
                               make_train_step)

ALL_ARCHS = sorted(ARCHS)


def _inputs(cfg, B=2, S=32, key=None):
    if key is None:
        key = jax.random.PRNGKey(7)
    F = cfg.frontend_tokens
    toks = jax.random.randint(key, (B, S - F), 0, cfg.vocab)
    embeds = (jax.random.normal(key, (B, F, cfg.d_model), jnp.float32)
              if F else None)
    return toks, embeds


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_arch(arch).reduced()
    params = pm.materialize(model_specs(cfg), jax.random.PRNGKey(0))
    B, S = 2, 32
    toks, embeds = _inputs(cfg, B, S)
    logits, _ = jax.jit(
        lambda p, t, e: forward(cfg, p, t, embeds=e, remat=False,
                                return_cache=False, cdt=jnp.float32)
    )(params, toks, embeds)
    vpad = -(-cfg.vocab // 16) * 16
    assert logits.shape == (B, S, vpad)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_arch(arch).reduced()
    params = pm.materialize(model_specs(cfg), jax.random.PRNGKey(1))
    opt = init_opt_state(params)
    B, S = 2, 32
    toks, embeds = _inputs(cfg, B, S)
    batch = {"tokens": toks, "labels": jnp.abs(toks) % cfg.vocab}
    if embeds is not None:
        batch["embeds"] = embeds
    step = jax.jit(make_train_step(
        cfg, OptConfig(total_steps=4, warmup_steps=1), cdt=jnp.float32))
    p2, o2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually moved
    moved = any(bool(jnp.any(a != b))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "deepseek-v2-236b",
                                  "mamba2-1.3b", "hymba-1.5b",
                                  "musicgen-medium"])
def test_decode_matches_full_forward(arch):
    """Token-by-token decode with a cache must agree with a fresh full
    forward over the same prefix (greedy argmax comparison)."""
    cfg = get_arch(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = pm.materialize(model_specs(cfg), key)
    B, S_prompt, S_max = 2, 16, 24
    toks, embeds = _inputs(cfg, B, S_prompt, key)

    prefill = jax.jit(make_prefill_step(cfg, S_max, cdt=jnp.float32))
    decode = jax.jit(make_decode_step(cfg, cdt=jnp.float32))
    last_logits, cache = prefill(params, toks, embeds)
    tok = jnp.argmax(last_logits, -1).astype(jnp.int32)[:, None]

    seq = toks
    for i in range(3):
        # full forward over extended prefix
        ext = jnp.concatenate([seq, tok], axis=1)
        full_logits, _ = jax.jit(
            lambda p, t, e: forward(cfg, p, t, embeds=e, remat=False,
                                    return_cache=False, cdt=jnp.float32)
        )(params, ext, embeds)
        want = np.asarray(jnp.argmax(full_logits[:, -1], -1))
        got_tok, cache = decode(params, cache, tok, jnp.int32(S_prompt + i))
        np.testing.assert_array_equal(np.asarray(got_tok), want)
        seq = ext
        tok = got_tok[:, None]


def test_shape_support_matrix():
    """long_500k only for sub-quadratic archs; decode everywhere."""
    sub = {a for a in ALL_ARCHS if get_arch(a).supports_shape("long_500k")}
    assert sub == {"mamba2-1.3b", "hymba-1.5b"}
    for a in ALL_ARCHS:
        assert get_arch(a).supports_shape("decode_32k")
        assert get_arch(a).supports_shape("train_4k")


def test_param_counts_in_expected_range():
    """Sanity: full-config parameter counts near the published sizes."""
    expect = {"qwen2-1.5b": (1.2e9, 2.0e9),
              "qwen2-7b": (6.5e9, 8.5e9),
              "deepseek-v2-236b": (2.0e11, 2.6e11),
              "qwen3-moe-30b-a3b": (2.6e10, 3.4e10),
              "mamba2-1.3b": (1.0e9, 1.7e9),
              "minicpm-2b": (2.2e9, 3.3e9)}
    for a, (lo, hi) in expect.items():
        n = get_arch(a).n_params()
        assert lo <= n <= hi, (a, n)
    # MoE active params much smaller than total
    ds = get_arch("deepseek-v2-236b")
    assert ds.n_active_params() < 0.2 * ds.n_params()
