"""Smoke for the relocated LLM decode demo (ex-``launch.serve`` flow).

The advisory service took over the ``repro.launch.serve`` entrypoint;
this pins the seed functionality that moved to
``repro.launch.decode_demo`` so the rename never silently drops it.
"""

import numpy as np


def test_decode_demo_smoke():
    from repro.launch.decode_demo import main

    out = main(["--arch", "qwen2-1.5b", "--batch", "1",
                "--prompt-len", "8", "--gen", "3"])
    assert set(out) == {"prefill_s", "decode_s", "tok_per_s", "tokens"}
    tokens = np.asarray(out["tokens"])
    assert tokens.shape == (1, 3)
    assert out["prefill_s"] > 0 and out["decode_s"] > 0
