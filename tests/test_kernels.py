"""Pallas kernel validation: interpret-mode kernel vs pure-jnp ref vs the
numpy worklist, swept over designs (event counts straddling the 128-lane
padding boundary), batch sizes, and FIFO widths (which flip the SRL/BRAM
read-latency path).  Results are integer-exact, so equality — not
allclose — is asserted.
"""

import numpy as np
import pytest

from repro.core.design import Design
from repro.core.simgraph import build_simgraph
from repro.core.config import EvalConfig
from repro.core.simulate import BatchedEvaluator, evaluate_np
from repro.designs.builder import map_stage, producer, sink, streams
from repro.designs.ddcf import mult_by_2
from repro.kernels.fifo_eval.ops import make_batched_eval


def tiny_chain(count=10, lanes=1, width=32):
    d = Design("tiny")
    a = streams(d, "a", lanes, width=width)
    b = streams(d, "b", lanes, width=width)
    producer(d, "p", a, [1.0] * count)
    map_stage(d, "m", a, b, count, ii=2, extra_delay=1)
    sink(d, "s", b, count)
    return d


DESIGNS = [
    ("tiny_sub128", lambda: tiny_chain(count=8)),          # E < 128 (pad)
    ("tiny_odd", lambda: tiny_chain(count=23, lanes=2)),   # E % 128 != 0
    ("wide64", lambda: tiny_chain(count=40, width=64)),    # BRAM rd-lat
    ("mult_by_2", lambda: mult_by_2(24)),                  # deadlocks
]


@pytest.mark.parametrize("name,factory", DESIGNS)
@pytest.mark.parametrize("batch", [1, 5, 8])
def test_kernel_matches_ref_and_worklist(name, factory, batch):
    d = factory()
    g = build_simgraph(d)
    rng = np.random.default_rng(hash(name) % 2**32)
    u = g.upper_bounds
    cfgs = np.stack([u, np.full(g.n_fifos, 2)] +
                    [rng.integers(2, np.maximum(3, u + 1))
                     for _ in range(max(batch - 2, 0))])[:batch]

    ev = BatchedEvaluator(g, EvalConfig(backend="numpy", max_iters=64))
    pallas_call = make_batched_eval(ev, interpret=True, max_iters=128)
    ref_call = make_batched_eval(ev, use_ref=True, max_iters=128)

    lat_p, bram_p, st_p = pallas_call(cfgs)
    lat_r, bram_r, st_r = ref_call(cfgs)
    np.testing.assert_array_equal(np.asarray(st_p), np.asarray(st_r))
    np.testing.assert_array_equal(np.asarray(bram_p), np.asarray(bram_r))
    np.testing.assert_allclose(np.asarray(lat_p), np.asarray(lat_r))

    for i in range(cfgs.shape[0]):
        lat_np, dead_np = evaluate_np(g, cfgs[i])
        if st_p[i] == 1:                      # DEADLOCK
            assert dead_np
        elif st_p[i] == 0:                    # CONVERGED
            assert not dead_np
            assert int(round(float(lat_p[i]))) == lat_np


def test_full_evaluator_pallas_backend_end_to_end():
    d = mult_by_2(24)
    g = build_simgraph(d)
    ev_np = BatchedEvaluator(g, EvalConfig(backend="numpy", max_iters=64))
    ev_pl = BatchedEvaluator(
        g, EvalConfig(backend="pallas", max_iters=128))
    rng = np.random.default_rng(3)
    cfgs = np.stack([rng.integers(2, 30, size=2) for _ in range(12)])
    a = ev_np.evaluate(cfgs)
    b = ev_pl.evaluate(cfgs)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


@pytest.mark.parametrize("batch", [1, 3, 5, 8, 12])
def test_condensed_kernel_ragged_batches_exact(batch):
    """The fused condensed kernel pads ragged batches to its row block
    internally (shrinking the block for small escalation buckets); every
    batch size must reproduce the row-at-a-time results exactly."""
    from repro.core.condense import condense_auto
    from repro.designs import make_design
    from repro.kernels.fifo_eval.ops import make_condensed_eval

    g = build_simgraph(make_design("gemm"))
    cg = condense_auto(g)[0]
    fused = make_condensed_eval(cg, max_iters=64)
    assert fused is not None
    rng = np.random.default_rng(11)
    u = np.asarray(g.upper_bounds, dtype=np.int64)
    cfgs = np.stack([np.maximum(2, (u * rng.uniform(0.4, 1.0, g.n_fifos))
                                .astype(int)) for _ in range(12)])
    cfgs = cfgs[:batch].astype(np.int32)
    got = [np.asarray(x) for x in fused(cfgs)]
    for i in range(batch):
        solo = [np.asarray(x) for x in fused(cfgs[i:i + 1])]
        for a, b in zip(got, solo):
            np.testing.assert_array_equal(a[i:i + 1], b,
                                          err_msg=f"row {i} of {batch}")


def test_pallas_cascade_end_to_end_matches_numpy():
    """BatchedEvaluator(backend='pallas') with the auto cascade (fused
    aggressive rung + scan safe rung + raw backstop) equals the numpy
    ground truth on a deadlock-heavy design."""
    from repro.designs import make_design
    g = build_simgraph(make_design("gemm"))
    rng = np.random.default_rng(7)
    u = np.asarray(g.upper_bounds, dtype=np.int64)
    cfgs = np.stack([np.ones_like(u), u] +
                    [rng.integers(1, u + 1) for _ in range(6)])
    a = BatchedEvaluator(
        g, EvalConfig(backend="numpy", max_iters=64)).evaluate(cfgs)
    b = BatchedEvaluator(
        g, EvalConfig(backend="pallas", max_iters=64)).evaluate(cfgs)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_kernel_iteration_cap_reports_unresolved_not_wrong():
    """With a tiny iteration cap the kernel must mark rows UNRESOLVED
    (status 2) rather than return a wrong latency as CONVERGED."""
    d = mult_by_2(32)
    g = build_simgraph(d)
    ev = BatchedEvaluator(g, EvalConfig(backend="numpy", max_iters=64))
    call = make_batched_eval(ev, interpret=True, max_iters=2)
    cfgs = np.array([[40, 2], [2, 2]])
    lat, _, st = call(cfgs)
    for i in range(2):
        if st[i] == 0:
            lat_np, dead_np = evaluate_np(g, cfgs[i])
            assert not dead_np and int(round(float(lat[i]))) == lat_np
        else:
            assert st[i] in (1, 2)
