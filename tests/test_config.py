"""EvalConfig + the deprecation shims of the v2 API redesign.

Every pre-EvalConfig spelling must keep working 1:1 (same behavior,
DeprecationWarning emitted), mixing old and new spellings must fail
loudly, and version-1 campaign checkpoints must still resume.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.core import (BatchedEvaluator, EvalConfig, FifoAdvisor,
                        build_simgraph, resolve_config)
from repro.designs import make_design


# ------------------------------------------------------------- EvalConfig
def test_evalconfig_is_frozen_and_json_round_trippable():
    cfg = EvalConfig(backend="jax", max_iters=32, shards=2,
                     local_bounds=True)
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.backend = "numpy"
    d = cfg.to_dict()
    assert json.loads(json.dumps(d)) == d
    assert EvalConfig.from_dict(d) == cfg
    assert cfg.replace(backend="numpy").backend == "numpy"
    assert cfg.replace(backend="numpy") != cfg


def test_resolve_config_rejects_unknowns_and_mixing():
    with pytest.raises(TypeError, match="unexpected"):
        resolve_config(None, {"max_itres": 64}, "X")
    with pytest.raises(TypeError, match="both"):
        resolve_config(EvalConfig(), {"max_iters": 64}, "X")
    with pytest.warns(DeprecationWarning, match="use_pallas"):
        cfg = resolve_config(None, {"use_pallas": True}, "X")
    assert cfg.backend == "pallas"


# --------------------------------------------------------- advisor shims
def test_advisor_legacy_kwargs_map_one_to_one():
    d = make_design("gemm")
    with pytest.warns(DeprecationWarning, match="FifoAdvisor"):
        old = FifoAdvisor(d, backend="numpy", max_iters=64)
    new = FifoAdvisor(d, EvalConfig(backend="numpy", max_iters=64))
    assert old.config == new.config
    r_old = old.run("grouped_random", budget=30, seed=0)
    r_new = new.run("grouped_random", budget=30, seed=0)
    assert np.array_equal(r_old.frontier_points, r_new.frontier_points)


def test_evaluator_legacy_forms_warn_and_match():
    g = build_simgraph(make_design("gemm"))
    new = BatchedEvaluator(g, EvalConfig(backend="numpy", max_iters=32))
    with pytest.warns(DeprecationWarning):
        kw = BatchedEvaluator(g, backend="numpy", max_iters=32)
    # the positional form warns twice: once for the form, once for the
    # mapped max_iters — capture both so neither leaks into the summary
    with pytest.warns(DeprecationWarning) as rec:
        pos = BatchedEvaluator(g, 32)
    assert any("positional" in str(w.message) for w in rec)
    assert kw.config == new.config
    assert pos.config.max_iters == 32
    cfgs = np.stack([g.upper_bounds, np.maximum(g.upper_bounds // 2, 2)])
    lat, bram, dead = new.evaluate(cfgs)
    lat2, bram2, dead2 = kw.evaluate(cfgs)
    assert np.array_equal(lat, lat2) and np.array_equal(dead, dead2)


def test_evaluator_default_max_iters_is_preserved():
    """The old evaluator default (64) must survive the redesign: a bare
    BatchedEvaluator(g) still caps batched backends at 64 iterations,
    while FifoAdvisor keeps its historical 256."""
    g = build_simgraph(make_design("gemm"))
    assert BatchedEvaluator(g).config.max_iters == 64
    assert FifoAdvisor(make_design("gemm")).config.max_iters == 256
    assert EvalConfig().max_iters == 256


# ------------------------------------------------------ CampaignSpec shims
def test_campaign_spec_legacy_fields_fold_into_eval():
    from repro.core.campaign import CampaignSpec
    with pytest.warns(DeprecationWarning, match="CampaignSpec"):
        spec = CampaignSpec(designs=("gemm",),
                            optimizers=("grouped_random",),
                            budget=20, backend="numpy", max_iters=64)
    assert spec.eval == EvalConfig(backend="numpy", max_iters=64)
    # the deprecated fields stay readable as views of ``eval``
    assert spec.backend == "numpy" and spec.max_iters == 64
    assert spec.shards is None
    with pytest.raises(TypeError, match="not both"):
        CampaignSpec(designs=("gemm",), optimizers=("grouped_random",),
                     eval=EvalConfig(), max_iters=64)


def test_v1_checkpoint_still_resumes(tmp_path):
    """A checkpoint written before EvalConfig existed (version 1, flat
    backend/max_iters/shards spec keys) must resume byte-identically."""
    from repro.core.campaign import Campaign, CampaignSpec
    from repro.core.campaign.state import save_checkpoint

    spec = CampaignSpec(designs=("gemm",), optimizers=("grouped_random",),
                        budget=30, eval=EvalConfig(max_iters=64))
    camp = Campaign(spec)
    camp.run(max_rounds=2)
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(camp, path)

    # rewrite the manifest to the version-1 schema
    with np.load(path, allow_pickle=False) as z:
        manifest = json.loads(str(z["manifest"]))
        arrays = {k: z[k] for k in z.files if k != "manifest"}
    manifest["version"] = 1
    ev = manifest["spec"].pop("eval")
    manifest["spec"]["backend"] = ev["backend"]
    manifest["spec"]["max_iters"] = ev["max_iters"]
    manifest["spec"]["shards"] = ev["shards"]
    v1_path = str(tmp_path / "ckpt_v1.npz")
    with open(v1_path, "wb") as f:
        np.savez_compressed(f, manifest=np.asarray(json.dumps(manifest)),
                            **arrays)

    resumed = Campaign.resume(v1_path, checkpoint_path=path)
    assert resumed.spec.eval == spec.eval
    got = resumed.run()
    ref = Campaign(spec).run()
    for key in ref.keys():
        assert np.array_equal(got[key].frontier_points,
                              ref[key].frontier_points), key
    camp.close()
